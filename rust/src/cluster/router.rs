//! The request router (`tmi route`): speaks the ordinary line protocol
//! to clients and forwards each request to the node that owns its
//! route, with a per-request deadline, capped exponential-backoff
//! retries against the next replica, and graceful degradation to
//! `err unavailable` when nobody can answer. The verb grammar and the
//! idempotent-vs-write retry rules are specified in
//! `docs/PROTOCOL.md`.
//!
//! Failure semantics, in order of what a client can observe:
//!
//! * **Never a hang** — every socket operation is bounded by what
//!   remains of [`RouterConfig::deadline`]; when it runs out the
//!   client gets a complete `err unavailable: ...` line.
//! * **Never a torn reply** — an upstream reply missing its trailing
//!   newline (or a multi-line reply cut mid-body) is discarded, not
//!   forwarded; the router retries or degrades.
//! * **No double-apply** — `feedback` and `train` mutate the model, so
//!   they are retried only on failures that prove the request was never
//!   processed (connect failure, `err busy` admission rejection). A
//!   reply lost *after* the request was sent degrades immediately
//!   instead of retrying.
//!
//! Membership comes from the control plane's `cluster` verb, polled in
//! the background; while the control plane is unreachable the router
//! keeps serving its last-known assignment, so a control-plane
//! partition degrades nothing.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::{Duration, Instant};

use crate::cluster::control::{fetch_cluster_view, ClusterView, NodeSpec};
use crate::cluster::ring::Ring;
use crate::coordinator::server::{read_protocol_line, LineRead};

/// Router knobs.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Control-plane address to poll membership from (`None` = static).
    pub control: Option<String>,
    /// Seed membership, used until (and whenever) the control plane is
    /// unreachable.
    pub nodes: Vec<NodeSpec>,
    /// Whole-request deadline: connect + retries + reply, end to end.
    pub deadline: Duration,
    /// First retry backoff; doubles per attempt.
    pub backoff_base: Duration,
    /// Backoff ceiling.
    pub backoff_cap: Duration,
    /// Membership poll cadence.
    pub poll: Duration,
    /// Virtual points per node (must match the control plane's).
    pub vnodes: u32,
}

impl RouterConfig {
    /// Config with default deadline/backoff for a static node list.
    pub fn new(nodes: Vec<NodeSpec>) -> RouterConfig {
        RouterConfig {
            control: None,
            nodes,
            deadline: Duration::from_secs(2),
            backoff_base: Duration::from_millis(10),
            backoff_cap: Duration::from_millis(200),
            poll: Duration::from_millis(500),
            vnodes: Ring::DEFAULT_VNODES,
        }
    }
}

/// Last-known membership: who exists, who is alive, and the ring that
/// places routes on them.
struct Membership {
    nodes: Vec<(String, String, bool)>, // (id, addr, alive)
    ring: Ring,
}

impl Membership {
    fn from_specs(specs: &[NodeSpec], vnodes: u32) -> Membership {
        let ids: Vec<&str> = specs.iter().map(|n| n.id.as_str()).collect();
        Membership {
            ring: Ring::with_vnodes(&ids, vnodes),
            nodes: specs
                .iter()
                .map(|n| (n.id.clone(), n.addr.clone(), true))
                .collect(),
        }
    }

    fn from_view(view: &ClusterView, vnodes: u32) -> Membership {
        let ids: Vec<&str> = view.nodes.iter().map(|n| n.id.as_str()).collect();
        Membership {
            ring: Ring::with_vnodes(&ids, vnodes),
            nodes: view
                .nodes
                .iter()
                .map(|n| (n.id.clone(), n.addr.clone(), n.alive))
                .collect(),
        }
    }
}

/// What shape of reply a forwarded verb produces.
#[derive(Clone, Copy, Debug, PartialEq)]
enum ReplyShape {
    /// One newline-terminated line.
    Single,
    /// `ok events=<n>` header plus `n` lines.
    Events,
    /// Prometheus exposition, terminated by a `# EOF` line.
    Prometheus,
}

/// One forwarding attempt's outcome.
enum Attempt {
    /// A complete reply (including upstream `err ...` answers, which
    /// are real answers and are forwarded verbatim).
    Reply(String),
    /// The node rejected admission (`err busy`): nothing was
    /// processed, safe to retry anywhere.
    Busy,
    /// Could not connect: nothing was sent, safe to retry.
    ConnectFail(String),
    /// The request was sent but the reply was lost or torn. NOT safe
    /// to retry non-idempotent verbs.
    SentButLost(String),
}

/// The routing core. Shared between connection threads; cheap to call
/// concurrently (membership is a short lock, forwarding holds none).
pub struct Router {
    cfg: RouterConfig,
    membership: Arc<Mutex<Membership>>,
}

impl Router {
    /// Router over the given config (static or control-plane-backed).
    pub fn new(cfg: RouterConfig) -> Router {
        let membership = Membership::from_specs(&cfg.nodes, cfg.vnodes);
        Router {
            cfg,
            membership: Arc::new(Mutex::new(membership)),
        }
    }

    /// One membership poll. On success the view replaces the current
    /// membership; on failure the last-known assignment stays in
    /// force — a partitioned control plane must not stop the data path.
    pub fn poll_membership(&self) {
        let Some(control) = &self.cfg.control else { return };
        match fetch_cluster_view(control, self.cfg.poll.max(Duration::from_millis(100))) {
            Ok(view) => {
                let fresh = Membership::from_view(&view, self.cfg.vnodes);
                *self.membership.lock().unwrap_or_else(PoisonError::into_inner) = fresh;
            }
            Err(_) => { /* keep last-known */ }
        }
    }

    /// Poll membership until `stop` (the background thread body).
    pub fn run_membership_poll(&self, stop: &AtomicBool) {
        while !stop.load(Ordering::Relaxed) {
            self.poll_membership();
            let t0 = Instant::now();
            while t0.elapsed() < self.cfg.poll && !stop.load(Ordering::Relaxed) {
                std::thread::sleep(Duration::from_millis(10).min(self.cfg.poll));
            }
        }
    }

    /// Membership as a [`ClusterView`] (the router's own `cluster`
    /// verb: last-known state, useful exactly when the control plane
    /// is not answering).
    fn membership_view(&self) -> ClusterView {
        let m = self.membership.lock().unwrap_or_else(PoisonError::into_inner);
        ClusterView {
            nodes: m
                .nodes
                .iter()
                .map(|(id, addr, alive)| crate::cluster::control::NodeView {
                    id: id.clone(),
                    addr: addr.clone(),
                    alive: *alive,
                    missed: 0,
                    missed_total: 0,
                    beats: 0,
                    replications: 0,
                    replication_failures: 0,
                })
                .collect(),
            routes: Vec::new(),
            generation: 0,
        }
    }

    /// Alive candidate addresses for `route`, primary first then the
    /// failover walk in ring order. `None` route (process-wide verbs
    /// like `metrics`) gets every alive node in id order.
    fn candidates(&self, route: Option<&str>) -> Vec<String> {
        let m = self.membership.lock().unwrap_or_else(PoisonError::into_inner);
        let addr_of = |id: &str| {
            m.nodes
                .iter()
                .find(|(nid, _, alive)| nid == id && *alive)
                .map(|(_, addr, _)| addr.clone())
        };
        match route {
            Some(key) => m
                .ring
                .replicas(key, m.ring.len())
                .into_iter()
                .filter_map(addr_of)
                .collect(),
            None => m
                .nodes
                .iter()
                .filter(|(_, _, alive)| *alive)
                .map(|(_, addr, _)| addr.clone())
                .collect(),
        }
    }

    /// Answer one protocol line: locally for `ping`/`cluster`,
    /// forwarded with failover for everything else. The reply is
    /// always a complete, newline-terminated protocol answer.
    pub fn respond(&self, line: &str) -> String {
        let trimmed = line.trim();
        if trimmed == "ping" {
            let v = self.membership_view();
            return format!("ok pong router nodes={} alive={}\n", v.nodes.len(), v.alive());
        }
        if trimmed == "cluster" {
            return self.membership_view().to_wire();
        }
        let (route, idempotent, shape) = classify(trimmed);
        self.forward(trimmed, route, idempotent, shape)
    }

    fn forward(
        &self,
        line: &str,
        route: Option<&str>,
        idempotent: bool,
        shape: ReplyShape,
    ) -> String {
        let start = Instant::now();
        let candidates = self.candidates(route);
        if candidates.is_empty() {
            return "err unavailable: no nodes alive\n".to_string();
        }
        let mut last_reason = String::from("deadline exhausted");
        let mut attempt: u32 = 0;
        loop {
            let remaining = self.cfg.deadline.saturating_sub(start.elapsed());
            if remaining.is_zero() {
                break;
            }
            let addr = &candidates[attempt as usize % candidates.len()];
            match try_once(addr, line, shape, remaining) {
                Attempt::Reply(reply) => return reply,
                Attempt::Busy => last_reason = format!("{addr}: busy"),
                Attempt::ConnectFail(e) => last_reason = e,
                Attempt::SentButLost(e) => {
                    if !idempotent {
                        // the node may have applied it — retrying could
                        // double-apply, so degrade with the truth
                        return format!("err unavailable: reply lost after send ({e})\n");
                    }
                    last_reason = e;
                }
            }
            attempt += 1;
            let shift = attempt.saturating_sub(1).min(20);
            let backoff = self
                .cfg
                .backoff_base
                .saturating_mul(1u32 << shift)
                .min(self.cfg.backoff_cap)
                .min(self.cfg.deadline.saturating_sub(start.elapsed()));
            std::thread::sleep(backoff);
        }
        format!("err unavailable: {} ({} attempts)\n", last_reason, attempt)
    }
}

/// Which route a line targets, whether a retry can double-apply, and
/// the reply shape to read back.
fn classify(trimmed: &str) -> (Option<&str>, bool, ReplyShape) {
    let first_word = |s: &str| s.split_whitespace().next();
    if trimmed == "metrics" {
        return (None, true, ReplyShape::Prometheus);
    }
    if let Some(rest) = trimmed.strip_prefix("feedback ") {
        return (first_word(rest), false, ReplyShape::Single);
    }
    if let Some(rest) = trimmed.strip_prefix("train ") {
        return (first_word(rest), false, ReplyShape::Single);
    }
    if let Some(rest) = trimmed.strip_prefix("stats ") {
        let rest = rest.trim();
        if let Some(model) = rest.strip_prefix("events ") {
            return (Some(model.trim()), true, ReplyShape::Events);
        }
        return (Some(rest), true, ReplyShape::Single);
    }
    let body = trimmed.strip_prefix("infer ").unwrap_or(trimmed);
    (first_word(body), true, ReplyShape::Single)
}

/// One attempt against one node, bounded by `remaining`.
fn try_once(addr: &str, line: &str, shape: ReplyShape, remaining: Duration) -> Attempt {
    let sock = match addr.parse::<std::net::SocketAddr>() {
        Ok(s) => s,
        Err(_) => {
            use std::net::ToSocketAddrs;
            match addr.to_socket_addrs().ok().and_then(|mut a| a.next()) {
                Some(s) => s,
                None => return Attempt::ConnectFail(format!("{addr}: unresolvable")),
            }
        }
    };
    let io = remaining.max(Duration::from_millis(1));
    let stream = match TcpStream::connect_timeout(&sock, io) {
        Ok(s) => s,
        Err(e) => return Attempt::ConnectFail(format!("{addr}: {e}")),
    };
    if stream
        .set_write_timeout(Some(io))
        .and_then(|()| stream.set_read_timeout(Some(io)))
        .is_err()
    {
        return Attempt::ConnectFail(format!("{addr}: socket setup failed"));
    }
    let mut stream = stream;
    if stream
        .write_all(format!("{line}\n").as_bytes())
        .and_then(|()| stream.flush())
        .is_err()
    {
        // a short write could have delivered the full line before the
        // failure, so this does NOT count as never-sent
        return Attempt::SentButLost(format!("{addr}: send failed"));
    }
    let mut reader = BufReader::new(stream);
    let mut head = String::new();
    match reader.read_line(&mut head) {
        Ok(0) => return Attempt::SentButLost(format!("{addr}: closed before reply")),
        Ok(_) if !head.ends_with('\n') => {
            return Attempt::SentButLost(format!("{addr}: torn reply"))
        }
        Ok(_) => {}
        Err(e) => return Attempt::SentButLost(format!("{addr}: {e}")),
    }
    if head.starts_with("err busy") {
        return Attempt::Busy;
    }
    match shape {
        ReplyShape::Single => Attempt::Reply(head),
        ReplyShape::Events => {
            if !head.starts_with("ok events=") {
                return Attempt::Reply(head); // an err line is the whole answer
            }
            let n: usize = head
                .trim_start_matches("ok events=")
                .trim()
                .parse()
                .unwrap_or(0);
            let mut out = head;
            for _ in 0..n {
                let mut l = String::new();
                match reader.read_line(&mut l) {
                    Ok(k) if k > 0 && l.ends_with('\n') => out.push_str(&l),
                    _ => return Attempt::SentButLost(format!("{addr}: events reply cut short")),
                }
            }
            Attempt::Reply(out)
        }
        ReplyShape::Prometheus => {
            if head.starts_with("err ") {
                return Attempt::Reply(head);
            }
            let mut out = head;
            loop {
                if out.ends_with("# EOF\n") {
                    return Attempt::Reply(out);
                }
                let mut l = String::new();
                match reader.read_line(&mut l) {
                    Ok(k) if k > 0 && l.ends_with('\n') => out.push_str(&l),
                    _ => return Attempt::SentButLost(format!("{addr}: metrics reply cut short")),
                }
            }
        }
    }
}

/// Serve the router on a listener until `stop`. Each connection gets a
/// thread; each line is answered by [`Router::respond`].
pub fn serve_router(
    listener: TcpListener,
    router: Arc<Router>,
    stop: Arc<AtomicBool>,
) -> std::io::Result<()> {
    listener.set_nonblocking(true)?;
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _addr)) => {
                let router = Arc::clone(&router);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    let _ = router_conn(stream, &router, &stop);
                });
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

fn router_conn(stream: TcpStream, router: &Router, stop: &AtomicBool) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(100)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    let mut line = String::new();
    loop {
        line.clear();
        match read_protocol_line(&mut reader, &mut line, stop)? {
            LineRead::Eof => return Ok(()),
            LineRead::TooLong => {
                stream.write_all(b"err line too long\n")?;
                continue;
            }
            LineRead::Line => {}
        }
        stream.write_all(router.respond(&line).as_bytes())?;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    /// A fake node: answers every line with `reply`, counting requests.
    /// `mode` tweaks behavior per scenario.
    enum FakeMode {
        Answer(&'static str),
        /// Read the request, then close without any reply.
        Swallow,
    }

    fn fake_node(mode: FakeMode) -> (String, Arc<AtomicUsize>, Arc<AtomicBool>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let seen = Arc::new(AtomicUsize::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        let (seen2, stop2) = (Arc::clone(&seen), Arc::clone(&stop));
        listener.set_nonblocking(true).unwrap();
        std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let mut reader = BufReader::new(stream.try_clone().unwrap());
                        let mut stream = stream;
                        let mut line = String::new();
                        if reader.read_line(&mut line).unwrap_or(0) == 0 {
                            continue;
                        }
                        seen2.fetch_add(1, Ordering::SeqCst);
                        match mode {
                            FakeMode::Answer(reply) => {
                                let _ = stream.write_all(reply.as_bytes());
                            }
                            FakeMode::Swallow => drop(stream),
                        }
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
        });
        (addr, seen, stop)
    }

    fn router_over(addrs: &[&str]) -> Router {
        let nodes: Vec<NodeSpec> = addrs
            .iter()
            .enumerate()
            .map(|(i, a)| NodeSpec {
                id: format!("n{i}"),
                addr: a.to_string(),
            })
            .collect();
        let mut cfg = RouterConfig::new(nodes);
        cfg.deadline = Duration::from_millis(800);
        cfg.backoff_base = Duration::from_millis(5);
        cfg.backoff_cap = Duration::from_millis(20);
        Router::new(cfg)
    }

    #[test]
    fn forwards_a_complete_reply_verbatim() {
        let (addr, seen, stop) = fake_node(FakeMode::Answer("ok 1 5 -3\n"));
        let router = router_over(&[&addr]);
        let reply = router.respond("infer cpu 1010\n");
        assert_eq!(reply, "ok 1 5 -3\n");
        assert_eq!(seen.load(Ordering::SeqCst), 1);
        stop.store(true, Ordering::Relaxed);
    }

    #[test]
    fn all_nodes_down_degrades_to_unavailable_within_deadline() {
        // port 1 refuses connections instantly on loopback
        let router = router_over(&["127.0.0.1:1"]);
        let t0 = Instant::now();
        let reply = router.respond("infer cpu 1010\n");
        assert!(
            reply.starts_with("err unavailable:"),
            "got {reply:?}"
        );
        assert!(reply.ends_with('\n'), "reply must be a complete line");
        assert!(
            t0.elapsed() < Duration::from_secs(3),
            "degradation must respect the deadline, took {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn busy_rejection_fails_over_to_the_next_replica() {
        let (busy_addr, _busy_seen, stop_a) =
            fake_node(FakeMode::Answer("err busy: connection limit reached\n"));
        let (ok_addr, ok_seen, stop_b) = fake_node(FakeMode::Answer("ok 0 7\n"));
        // every candidate is tried in ring order; whichever is first,
        // the busy one is skipped and the healthy one answers
        let router = router_over(&[&busy_addr, &ok_addr]);
        let reply = router.respond("infer cpu 1010\n");
        assert_eq!(reply, "ok 0 7\n");
        assert_eq!(ok_seen.load(Ordering::SeqCst), 1);
        stop_a.store(true, Ordering::Relaxed);
        stop_b.store(true, Ordering::Relaxed);
    }

    #[test]
    fn lost_reply_after_send_never_retries_feedback() {
        let (addr, seen, stop) = fake_node(FakeMode::Swallow);
        let router = router_over(&[&addr]);
        let reply = router.respond("feedback cpu 1 1010\n");
        assert!(
            reply.starts_with("err unavailable: reply lost after send"),
            "got {reply:?}"
        );
        // exactly one delivery: a retry here could double-apply
        assert_eq!(seen.load(Ordering::SeqCst), 1);
        stop.store(true, Ordering::Relaxed);
    }

    #[test]
    fn lost_reply_retries_idempotent_infer() {
        let (addr, seen, stop) = fake_node(FakeMode::Swallow);
        let router = router_over(&[&addr]);
        let reply = router.respond("infer cpu 1010\n");
        assert!(reply.starts_with("err unavailable:"), "got {reply:?}");
        assert!(
            seen.load(Ordering::SeqCst) > 1,
            "idempotent requests should have retried"
        );
        stop.store(true, Ordering::Relaxed);
    }

    #[test]
    fn local_verbs_answer_without_nodes() {
        let router = router_over(&["127.0.0.1:1"]);
        assert!(router.respond("ping\n").starts_with("ok pong router nodes=1"));
        assert!(router.respond("cluster\n").starts_with("ok nodes=1"));
    }

    #[test]
    fn classify_extracts_route_and_idempotency() {
        assert_eq!(classify("infer cpu 101"), (Some("cpu"), true, ReplyShape::Single));
        assert_eq!(classify("cpu 101"), (Some("cpu"), true, ReplyShape::Single));
        assert_eq!(
            classify("feedback cpu 1 101"),
            (Some("cpu"), false, ReplyShape::Single)
        );
        assert_eq!(classify("train cpu 1:101"), (Some("cpu"), false, ReplyShape::Single));
        assert_eq!(classify("stats cpu"), (Some("cpu"), true, ReplyShape::Single));
        assert_eq!(
            classify("stats events cpu"),
            (Some("cpu"), true, ReplyShape::Events)
        );
        assert_eq!(classify("metrics"), (None, true, ReplyShape::Prometheus));
    }
}
