//! Deterministic consistent-hash ring mapping route names to serving
//! nodes.
//!
//! Each node contributes [`Ring::DEFAULT_VNODES`] virtual points on a
//! 64-bit circle; a route is owned by the first node point clockwise of
//! the route's hash. Placement is a pure function of the member set —
//! every control plane, router, and test that builds a ring over the
//! same nodes computes the same assignment with no coordination.
//!
//! Membership changes reshuffle a *bounded* fraction of routes: adding
//! a node moves only the routes it captures (~1/N of the total), and
//! removing a node moves only the routes it owned. Everything else
//! keeps its owner, which is what lets the cluster re-replicate after
//! an eviction without a full redeploy.

/// Consistent-hash ring over named nodes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Ring {
    /// Sorted, deduplicated member ids.
    nodes: Vec<String>,
    /// Virtual points: `(hash, index into nodes)`, sorted by hash.
    points: Vec<(u64, u32)>,
    vnodes: u32,
}

impl Ring {
    /// Virtual points per node. 64 keeps the max/min owner share
    /// within roughly a factor of two of ideal (see the balance test)
    /// while a full rebuild stays trivially cheap at cluster sizes
    /// measured in dozens.
    pub const DEFAULT_VNODES: u32 = 64;

    /// Build a ring over `nodes` with the default vnode count.
    pub fn new<S: AsRef<str>>(nodes: &[S]) -> Ring {
        Ring::with_vnodes(nodes, Ring::DEFAULT_VNODES)
    }

    /// Build a ring with an explicit vnode count (floored at 1).
    pub fn with_vnodes<S: AsRef<str>>(nodes: &[S], vnodes: u32) -> Ring {
        let mut ids: Vec<String> = nodes.iter().map(|n| n.as_ref().to_string()).collect();
        ids.sort();
        ids.dedup();
        let mut ring = Ring {
            nodes: ids,
            points: Vec::new(),
            vnodes: vnodes.max(1),
        };
        ring.rebuild();
        ring
    }

    fn rebuild(&mut self) {
        self.points.clear();
        self.points.reserve(self.nodes.len() * self.vnodes as usize);
        for (i, node) in self.nodes.iter().enumerate() {
            for v in 0..self.vnodes {
                self.points.push((hash64(&format!("{node}#{v}")), i as u32));
            }
        }
        self.points.sort_unstable();
    }

    /// Add a member (no-op if already present). Only routes the new
    /// node captures change owner.
    pub fn add(&mut self, node: &str) {
        if self.nodes.iter().any(|n| n == node) {
            return;
        }
        self.nodes.push(node.to_string());
        self.nodes.sort();
        self.rebuild();
    }

    /// Remove a member (no-op if absent). Only routes the departed
    /// node owned change owner.
    pub fn remove(&mut self, node: &str) {
        let before = self.nodes.len();
        self.nodes.retain(|n| n != node);
        if self.nodes.len() != before {
            self.rebuild();
        }
    }

    /// Sorted member ids.
    pub fn nodes(&self) -> &[String] {
        &self.nodes
    }

    /// Number of member nodes.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// True if the ring has no members.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// True if `node` is a member.
    pub fn contains(&self, node: &str) -> bool {
        self.nodes.iter().any(|n| n == node)
    }

    /// The route's primary owner (`None` on an empty ring).
    pub fn owner(&self, key: &str) -> Option<&str> {
        self.replica_iter(key).next()
    }

    /// The first `n` distinct owners clockwise from the route's hash —
    /// primary first, then the failover order a router walks. Returns
    /// fewer than `n` when the ring has fewer members.
    pub fn replicas(&self, key: &str, n: usize) -> Vec<&str> {
        self.replica_iter(key).take(n).collect()
    }

    /// Distinct owners in ring order starting at `key`'s hash.
    fn replica_iter(&self, key: &str) -> impl Iterator<Item = &str> {
        let start = if self.points.is_empty() {
            0
        } else {
            // first point clockwise of (at or after) the key hash,
            // wrapping past the top of the circle
            let kh = hash64(key);
            let i = self.points.partition_point(|&(h, _)| h < kh);
            if i == self.points.len() {
                0
            } else {
                i
            }
        };
        let mut seen = vec![false; self.nodes.len()];
        let n = self.points.len();
        (0..n).filter_map(move |k| {
            let idx = self.points[(start + k) % n].1 as usize;
            if std::mem::replace(&mut seen[idx], true) {
                None
            } else {
                Some(self.nodes[idx].as_str())
            }
        })
    }
}

/// 64-bit point hash: FNV-1a over the bytes, then a splitmix64
/// finalizer to break up FNV's weak avalanche on short keys. Stable by
/// construction — never change these constants, or every deployed ring
/// disagrees about ownership across versions.
fn hash64(key: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in key.as_bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    let mut z = h;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    fn routes(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("route-{i}")).collect()
    }

    fn shares(ring: &Ring, keys: &[String]) -> HashMap<String, usize> {
        let mut counts: HashMap<String, usize> = HashMap::new();
        for k in keys {
            let owner = ring.owner(k).expect("non-empty ring").to_string();
            *counts.entry(owner).or_insert(0) += 1;
        }
        counts
    }

    #[test]
    fn placement_is_deterministic() {
        let a = Ring::new(&["node-b", "node-a", "node-a", "node-c"]);
        let b = Ring::new(&["node-a", "node-c", "node-b"]);
        assert_eq!(a, b);
        for k in routes(50) {
            assert_eq!(a.owner(&k), b.owner(&k));
        }
    }

    #[test]
    fn balance_within_factor_two_of_ideal() {
        // 200 routes over 4 nodes: every owner's share must land in
        // [ideal/2, 2*ideal]. Deterministic — the hash has no seed.
        let keys = routes(200);
        let ring = Ring::new(&["node-a", "node-b", "node-c", "node-d"]);
        let counts = shares(&ring, &keys);
        let ideal = keys.len() / ring.len();
        for node in ring.nodes() {
            let share = counts.get(node).copied().unwrap_or(0);
            assert!(
                share >= ideal / 2 && share <= ideal * 2,
                "{node} owns {share} of {} (ideal {ideal})",
                keys.len()
            );
        }
    }

    #[test]
    fn adding_a_node_moves_only_captured_routes() {
        let keys = routes(200);
        let four = Ring::new(&["node-a", "node-b", "node-c", "node-d"]);
        let mut five = four.clone();
        five.add("node-e");
        let mut moved = 0usize;
        for k in &keys {
            let before = four.owner(k).unwrap();
            let after = five.owner(k).unwrap();
            if before != after {
                // a moved route can only have moved TO the new node
                assert_eq!(after, "node-e", "{k} moved {before} -> {after}");
                moved += 1;
            }
        }
        let ideal = keys.len() / five.len();
        assert!(moved > 0, "new node captured nothing");
        assert!(moved <= 2 * ideal, "moved {moved}, ideal {ideal} — reshuffle not bounded");
    }

    #[test]
    fn removing_a_node_moves_only_its_routes() {
        let keys = routes(200);
        let four = Ring::new(&["node-a", "node-b", "node-c", "node-d"]);
        let mut three = four.clone();
        three.remove("node-c");
        let mut moved = 0usize;
        for k in &keys {
            let before = four.owner(k).unwrap();
            let after = three.owner(k).unwrap();
            if before == "node-c" {
                assert_ne!(after, "node-c");
                moved += 1;
            } else {
                // survivors keep every route they already owned
                assert_eq!(before, after, "{k} moved off a surviving node");
            }
        }
        let ideal = keys.len() / four.len();
        assert!(moved <= 2 * ideal, "node-c owned {moved}, ideal {ideal}");
    }

    #[test]
    fn replicas_are_distinct_and_lead_with_owner() {
        let ring = Ring::new(&["node-a", "node-b", "node-c", "node-d"]);
        for k in routes(50) {
            let reps = ring.replicas(&k, 3);
            assert_eq!(reps.len(), 3);
            assert_eq!(reps[0], ring.owner(&k).unwrap());
            let mut uniq = reps.clone();
            uniq.sort();
            uniq.dedup();
            assert_eq!(uniq.len(), 3, "duplicate replica for {k}: {reps:?}");
        }
        // asking for more replicas than members returns every member
        assert_eq!(ring.replicas("route-0", 9).len(), 4);
        assert!(Ring::new::<&str>(&[]).owner("route-0").is_none());
    }

    #[test]
    fn membership_ops_are_idempotent() {
        let mut ring = Ring::new(&["node-a", "node-b"]);
        let snap = ring.clone();
        ring.add("node-a");
        ring.remove("node-zzz");
        assert_eq!(ring, snap);
        ring.remove("node-a");
        ring.remove("node-b");
        assert!(ring.is_empty());
        assert!(ring.replicas("route-1", 2).is_empty());
    }
}
