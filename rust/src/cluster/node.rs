//! A cluster serving node: the single-process coordinator plus the
//! wire surface the control plane drives — `ping` liveness probes and
//! `replicate` snapshot pushes. Everything else on the port is the
//! ordinary line protocol, answered by the node's own
//! [`CoordinatorHandle`], so a node is a drop-in superset of
//! `tmi serve`.
//!
//! Replication reuses the `io` v3 framing end to end: the control
//! plane ships the registry's checksummed byte image verbatim, and the
//! node re-verifies the CRC-32 footer before *anything* is installed.
//! A torn or corrupted transfer is refused with `err truncated` /
//! `err corrupt`, a [`EventKind::Quarantine`] journal event, and the
//! previously serving version untouched — a swap propagates
//! cluster-wide without torn versions, or not at all.

use std::io::{BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, PoisonError, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::server::{
    note_conn_rejected, read_protocol_line, respond_line, Coordinator, CoordinatorHandle,
    LineRead, RouteConfig, ServeOptions,
};
use crate::engine::{InferMode, ModelSnapshot};
use crate::obs::{journal, EventKind};
use crate::tm::io as model_io;

/// Largest accepted `replicate` body. Generous: a paper-scale model
/// (MNIST, 8k clauses) serializes to a few tens of MiB.
const MAX_REPLICATE_BYTES: u64 = 1 << 28;

/// Node-side knobs beyond the base [`ServeOptions`].
#[derive(Clone, Debug)]
pub struct NodeOptions {
    /// Cluster-unique node id (`--node-id`), echoed in `ping` replies
    /// and journal events.
    pub id: String,
    /// Sizing for routes created by replication pushes.
    pub route_config: RouteConfig,
    /// Abandon a `replicate` body that stalls longer than this — the
    /// connection is dropped and the control plane retries.
    pub transfer_deadline: Duration,
}

impl NodeOptions {
    /// Options with defaults for everything but the node id.
    pub fn new(id: impl Into<String>) -> NodeOptions {
        NodeOptions {
            id: id.into(),
            route_config: RouteConfig::default(),
            transfer_deadline: Duration::from_secs(30),
        }
    }
}

/// Shared node state: the coordinator (locked only to create routes)
/// and the handle connection threads actually serve from. The handle
/// is regenerated after a route registration; swaps of existing routes
/// go through the shared `SwapCell`, so readers never wait on the
/// coordinator lock.
pub struct NodeState {
    opts: NodeOptions,
    coord: Mutex<Option<Coordinator>>,
    handle: RwLock<CoordinatorHandle>,
}

/// What a successful [`NodeState::install`] did.
#[derive(Clone, Debug, PartialEq)]
pub struct Installed {
    /// Route the image was installed under.
    pub route: String,
    /// Registry version of the installed image.
    pub version: u64,
    /// Route swap generation after the install (0 = fresh route).
    pub generation: u64,
}

impl NodeState {
    /// Wrap a coordinator (possibly with pre-registered routes) as a
    /// cluster node.
    pub fn new(coord: Coordinator, opts: NodeOptions) -> NodeState {
        let handle = coord.handle();
        NodeState {
            opts,
            coord: Mutex::new(Some(coord)),
            handle: RwLock::new(handle),
        }
    }

    /// This node's id.
    pub fn id(&self) -> &str {
        &self.opts.id
    }

    /// The current routing handle (snapshots the route table).
    pub fn handle(&self) -> CoordinatorHandle {
        self.handle
            .read()
            .unwrap_or_else(PoisonError::into_inner)
            .clone()
    }

    /// Verify and install a replicated snapshot image. The CRC check
    /// runs over the complete image *before* any route state changes;
    /// failures leave the serving version untouched and are journaled
    /// as quarantines.
    pub fn install(
        &self,
        route: &str,
        version: u64,
        infer: InferMode,
        image: &[u8],
    ) -> Result<Installed, String> {
        let tm = model_io::load_from(&mut &image[..]).map_err(|e| {
            journal().emit(EventKind::Quarantine {
                route: route.to_string(),
                version,
                reason: e.to_string(),
            });
            match e {
                model_io::ModelIoError::Truncated => format!("truncated: {e}"),
                other => format!("corrupt: {other}"),
            }
        })?;
        let snapshot = Arc::new(ModelSnapshot::with_mode(tm, version, infer));
        let mut guard = self.coord.lock().unwrap_or_else(PoisonError::into_inner);
        let Some(coord) = guard.as_mut() else {
            return Err("node shutting down".to_string());
        };
        let known = coord.models().iter().any(|m| m == route);
        if known {
            coord.swap(route, snapshot).map_err(|e| e.to_string())?;
        } else {
            coord.register_model(route, snapshot, self.opts.route_config);
            *self.handle.write().unwrap_or_else(PoisonError::into_inner) = coord.handle();
        }
        let generation = coord.stats(route).and_then(|st| st.generation).unwrap_or(0);
        journal().emit(EventKind::Replicate {
            node: self.opts.id.clone(),
            route: route.to_string(),
            version,
        });
        Ok(Installed {
            route: route.to_string(),
            version,
            generation,
        })
    }

    /// One-line `ping` reply: identity plus how many routes are live.
    fn pong(&self) -> String {
        let routes = self.handle().models().len();
        format!("ok pong node={} routes={routes}\n", self.opts.id)
    }

    /// Count-prefixed node-local cluster view (the `cluster` verb on a
    /// node port): identity line, then one line per served route.
    fn cluster_view(&self) -> String {
        use std::fmt::Write as _;
        let handle = self.handle();
        let models = handle.models();
        let mut out = format!("ok node={} routes={}\n", self.opts.id, models.len());
        for m in &models {
            let st = handle.stats(m);
            let opt = |v: Option<u64>| v.map(|v| v.to_string()).unwrap_or_else(|| "-".into());
            let (v, g) = st
                .map(|st| (opt(st.version), opt(st.generation)))
                .unwrap_or_else(|| ("-".into(), "-".into()));
            let _ = writeln!(out, "route name={m} version={v} generation={g}");
        }
        out
    }

    /// Close every route and join the workers (close-then-drain, as
    /// [`Coordinator::shutdown`]).
    pub fn shutdown(&self) {
        let coord = self
            .coord
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .take();
        if let Some(c) = coord {
            c.shutdown();
        }
    }
}

/// Serve the node protocol: the base line protocol plus `ping`,
/// `cluster`, and `replicate`. Accept loop mirrors
/// [`crate::coordinator::server::serve_tcp_with`] — nonblocking with a
/// reaped connection cap answering `err busy` (counted in
/// `conn_rejected`).
pub fn serve_node(
    listener: TcpListener,
    node: Arc<NodeState>,
    stop: Arc<AtomicBool>,
    opts: ServeOptions,
) -> std::io::Result<()> {
    listener.set_nonblocking(true)?;
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _addr)) => {
                conns.retain(|c| !c.is_finished());
                if conns.len() >= opts.max_conns {
                    note_conn_rejected();
                    let mut stream = stream;
                    let _ = stream.write_all(b"err busy: connection limit reached\n");
                    continue;
                }
                let node = Arc::clone(&node);
                let stop_conn = Arc::clone(&stop);
                conns.push(std::thread::spawn(move || {
                    let _ = node_conn(stream, &node, &stop_conn, opts.read_timeout);
                }));
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => return Err(e),
        }
    }
    for c in conns {
        let _ = c.join();
    }
    Ok(())
}

fn node_conn(
    stream: TcpStream,
    node: &NodeState,
    stop: &Arc<AtomicBool>,
    read_timeout: Duration,
) -> std::io::Result<()> {
    stream.set_read_timeout(Some(read_timeout.max(Duration::from_millis(1))))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    let mut line = String::new();
    loop {
        line.clear();
        match read_protocol_line(&mut reader, &mut line, stop)? {
            LineRead::Eof => return Ok(()),
            LineRead::TooLong => {
                stream.write_all(b"err line too long\n")?;
                continue;
            }
            LineRead::Line => {}
        }
        let trimmed = line.trim();
        if trimmed == "ping" {
            stream.write_all(node.pong().as_bytes())?;
            continue;
        }
        if trimmed == "cluster" {
            stream.write_all(node.cluster_view().as_bytes())?;
            continue;
        }
        if let Some(header) = trimmed.strip_prefix("replicate ") {
            let reply = match respond_replicate(header, &mut reader, node, stop) {
                Ok(reply) => reply,
                Err(e) => {
                    // transfer died mid-body: best-effort error reply,
                    // then drop the connection — the control retries
                    let _ = stream.write_all(format!("err truncated: {e}\n").as_bytes());
                    return Ok(());
                }
            };
            stream.write_all(reply.as_bytes())?;
            continue;
        }
        let handle = node.handle();
        let (reply, _) = respond_line(&line, &handle);
        stream.write_all(reply.as_bytes())?;
    }
}

/// `replicate <route> <version> <infer> <len>` + `<len>` raw bytes of
/// a v3 model image. Returns the protocol reply, or `Err` when the
/// body could not be read at all (connection-fatal).
fn respond_replicate(
    header: &str,
    reader: &mut BufReader<TcpStream>,
    node: &NodeState,
    stop: &AtomicBool,
) -> std::io::Result<String> {
    let mut parts = header.split_whitespace();
    let (route, version, infer, len) = match (
        parts.next(),
        parts.next().and_then(|v| v.parse::<u64>().ok()),
        parts.next().and_then(|m| m.parse::<InferMode>().ok()),
        parts.next().and_then(|l| l.parse::<u64>().ok()),
    ) {
        (Some(r), Some(v), Some(m), Some(l)) => (r, v, m, l),
        _ => {
            return Ok("err expected 'replicate <route> <version> <infer> <len>'\n".to_string())
        }
    };
    if len > MAX_REPLICATE_BYTES {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("replicate body of {len} bytes exceeds cap"),
        ));
    }
    let mut image = vec![0u8; len as usize];
    read_body(reader, &mut image, stop, node.opts.transfer_deadline)?;
    Ok(match node.install(route, version, infer, &image) {
        Ok(done) => format!(
            "ok replicated route={} version={} generation={}\n",
            done.route, done.version, done.generation
        ),
        Err(e) => format!("err {e}\n"),
    })
}

/// Read exactly `buf.len()` body bytes, tolerating read-timeout ticks
/// (shutdown check) up to the transfer deadline. EOF or a stall is an
/// error: a short body is a torn transfer, never installed.
fn read_body(
    reader: &mut BufReader<TcpStream>,
    buf: &mut [u8],
    stop: &AtomicBool,
    deadline: Duration,
) -> std::io::Result<()> {
    let start = Instant::now();
    let mut filled = 0usize;
    while filled < buf.len() {
        match reader.read(&mut buf[filled..]) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    format!("replication body ended at {filled}/{} bytes", buf.len()),
                ));
            }
            Ok(n) => filled += n,
            Err(ref e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if stop.load(Ordering::Relaxed) || start.elapsed() > deadline {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::TimedOut,
                        format!("replication body stalled at {filled}/{} bytes", buf.len()),
                    ));
                }
            }
            Err(e) => return Err(e),
        }
    }
    Ok(())
}
