//! Clustered serving: control plane + N nodes speaking the existing
//! line protocol.
//!
//! The wire protocol every role speaks — verbs, error lines, timeout
//! and idempotency semantics — is specified in `docs/PROTOCOL.md`.
//!
//! The single-process server scales out without changing the client
//! protocol or the on-disk formats:
//!
//! * [`ring`] — a deterministic consistent-hash ring maps route names
//!   to nodes; membership changes reshuffle a bounded ~1/N of routes.
//! * [`node`] — `tmi serve --node-id <id>` wraps the ordinary
//!   coordinator with `ping` liveness and `replicate` snapshot pushes
//!   (CRC-verified before install, torn transfers refused).
//! * [`control`] — `tmi control` heartbeats every node, evicts on
//!   missed beats, re-admits on recovery, and replicates the
//!   registry's published images to each route's owners.
//! * [`router`] — `tmi route` forwards client requests to the owning
//!   node with a per-request deadline, backed-off failover across
//!   replicas, and `err unavailable` (never a hang, never a torn
//!   reply) when nobody can answer.
//!
//! [`faultnet`] is the TCP chaos proxy the fault-injection tests drive
//! between these pieces; it is not part of the serving surface.

pub mod control;
#[doc(hidden)]
pub mod faultnet;
pub mod node;
pub mod ring;
pub mod router;

pub use control::{
    fetch_cluster_view, push_snapshot, serve_control, ClusterView, ControlConfig, ControlPlane,
    NodeSpec, NodeView, RouteView,
};
pub use node::{serve_node, Installed, NodeOptions, NodeState};
pub use ring::Ring;
pub use router::{serve_router, Router, RouterConfig};
