//! The cluster control plane (`tmi control`): node liveness via
//! heartbeats with missed-beat eviction and re-admission, snapshot
//! replication from the durable registry to the owning nodes, and a
//! `cluster` protocol verb exposing the whole picture.
//!
//! Liveness: every [`ControlConfig::heartbeat`] the plane `ping`s each
//! configured node. A node that misses
//! [`ControlConfig::miss_threshold`] consecutive beats is evicted from
//! the serving set (`node_evict` journal event) — owners are re-picked
//! from the ring's next replicas, a bounded reshuffle. The first
//! successful ping re-admits it (`node_up`) and forces a full
//! re-replication of its routes, since its state is unknown.
//!
//! Replication: the plane polls the registry manifest generation and
//! pushes each route's published version — the registry's checksummed
//! `io` v3 byte image, shipped verbatim — to every owner that doesn't
//! have it yet. The node re-verifies the CRC before installing
//! ([`crate::cluster::node::NodeState::install`]), so a transfer torn
//! or corrupted anywhere between registry disk and node memory is
//! refused and retried on a later tick, never served. A `swap`
//! (publish) therefore propagates cluster-wide without torn versions.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::{Duration, Instant};

use crate::cluster::ring::Ring;
use crate::engine::InferMode;
use crate::obs::prometheus::PromWriter;
use crate::obs::{journal, EventKind};
use crate::registry::{read_generation, Registry};
use crate::util::crc32;

/// One configured node: `id@host:port` on the CLI.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NodeSpec {
    /// Node id (stable across restarts; hashes onto the ring).
    pub id: String,
    /// `host:port` the node's line protocol listens on.
    pub addr: String,
}

impl NodeSpec {
    /// Parse `id@host:port`.
    pub fn parse(s: &str) -> Result<NodeSpec, String> {
        let (id, addr) = s
            .split_once('@')
            .ok_or_else(|| format!("bad node spec '{s}': expected id@host:port"))?;
        if id.is_empty() || addr.is_empty() || id.contains(char::is_whitespace) {
            return Err(format!("bad node spec '{s}': expected id@host:port"));
        }
        Ok(NodeSpec {
            id: id.to_string(),
            addr: addr.to_string(),
        })
    }

    /// Parse a comma-separated list of specs.
    pub fn parse_list(s: &str) -> Result<Vec<NodeSpec>, String> {
        let specs: Vec<NodeSpec> = s
            .split(',')
            .filter(|t| !t.trim().is_empty())
            .map(|t| NodeSpec::parse(t.trim()))
            .collect::<Result<_, _>>()?;
        if specs.is_empty() {
            return Err("empty node list".to_string());
        }
        Ok(specs)
    }
}

/// Control-plane knobs.
#[derive(Clone, Debug)]
pub struct ControlConfig {
    /// The static fleet membership.
    pub nodes: Vec<NodeSpec>,
    /// Registry directory replication reads from.
    pub registry_dir: PathBuf,
    /// Heartbeat cadence.
    pub heartbeat: Duration,
    /// Consecutive missed beats before eviction.
    pub miss_threshold: u32,
    /// Owners per route (primary + failover replicas).
    pub replicas: usize,
    /// Per-probe connect/read timeout.
    pub probe_timeout: Duration,
    /// Per-push connect/read/write timeout (whole-image transfers).
    pub push_timeout: Duration,
    /// Virtual points per node on the ring.
    pub vnodes: u32,
}

impl ControlConfig {
    /// Config with the default heartbeat/replication cadence.
    pub fn new(nodes: Vec<NodeSpec>, registry_dir: impl Into<PathBuf>) -> ControlConfig {
        ControlConfig {
            nodes,
            registry_dir: registry_dir.into(),
            heartbeat: Duration::from_millis(500),
            miss_threshold: 3,
            replicas: 2,
            probe_timeout: Duration::from_millis(500),
            push_timeout: Duration::from_secs(10),
            vnodes: Ring::DEFAULT_VNODES,
        }
    }
}

/// One node's health as the control plane sees it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NodeView {
    /// Node id.
    pub id: String,
    /// `host:port` of the node.
    pub addr: String,
    /// In the serving set (answering heartbeats).
    pub alive: bool,
    /// Current consecutive missed-beat streak.
    pub missed: u64,
    /// Lifetime missed beats (Prometheus counter).
    pub missed_total: u64,
    /// Lifetime successful heartbeats.
    pub beats: u64,
    /// Successful replication pushes to this node.
    pub replications: u64,
    /// Failed/refused replication pushes to this node.
    pub replication_failures: u64,
}

/// One route's placement.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RouteView {
    /// Route (model) name.
    pub name: String,
    /// Published version being replicated.
    pub version: u64,
    /// Owners in ring order (alive nodes only).
    pub owners: Vec<String>,
}

/// Snapshot of cluster state, served by the `cluster` verb and the
/// control plane's `metrics` exposition.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ClusterView {
    /// Every configured node with its liveness state.
    pub nodes: Vec<NodeView>,
    /// Every registry route with its current owner set.
    pub routes: Vec<RouteView>,
    /// Registry manifest generation last replicated from.
    pub generation: u64,
}

impl ClusterView {
    /// Number of nodes currently considered up.
    pub fn alive(&self) -> usize {
        self.nodes.iter().filter(|n| n.alive).count()
    }

    /// Count-prefixed wire form: a header naming how many `node` and
    /// `route` lines follow, so line-protocol clients know exactly how
    /// much to read.
    pub fn to_wire(&self) -> String {
        use std::fmt::Write as _;
        let mut out = format!(
            "ok nodes={} alive={} routes={} generation={}\n",
            self.nodes.len(),
            self.alive(),
            self.routes.len(),
            self.generation
        );
        for n in &self.nodes {
            let state = if n.alive { "up" } else { "down" };
            let _ = writeln!(
                out,
                "node id={} addr={} state={state} missed={} beats={}",
                n.id, n.addr, n.missed, n.beats
            );
        }
        for r in &self.routes {
            let _ = writeln!(
                out,
                "route name={} version={} owners={}",
                r.name,
                r.version,
                r.owners.join(",")
            );
        }
        out
    }

    /// Parse the wire form back (the router's membership poll).
    pub fn from_wire(header: &str, lines: &[String]) -> Result<ClusterView, String> {
        let fields = kv_fields(header.trim().strip_prefix("ok ").unwrap_or(header.trim()));
        let generation = fields
            .get("generation")
            .and_then(|v| v.parse().ok())
            .unwrap_or(0);
        let mut view = ClusterView {
            generation,
            ..ClusterView::default()
        };
        for line in lines {
            let line = line.trim();
            if let Some(rest) = line.strip_prefix("node ") {
                let f = kv_fields(rest);
                view.nodes.push(NodeView {
                    id: f.get("id").cloned().ok_or("node line missing id")?,
                    addr: f.get("addr").cloned().ok_or("node line missing addr")?,
                    alive: f.get("state").map(|s| s == "up").unwrap_or(false),
                    missed: f.get("missed").and_then(|v| v.parse().ok()).unwrap_or(0),
                    missed_total: 0,
                    beats: f.get("beats").and_then(|v| v.parse().ok()).unwrap_or(0),
                    replications: 0,
                    replication_failures: 0,
                });
            } else if let Some(rest) = line.strip_prefix("route ") {
                let f = kv_fields(rest);
                view.routes.push(RouteView {
                    name: f.get("name").cloned().ok_or("route line missing name")?,
                    version: f.get("version").and_then(|v| v.parse().ok()).unwrap_or(0),
                    owners: f
                        .get("owners")
                        .map(|o| {
                            o.split(',')
                                .filter(|s| !s.is_empty())
                                .map(str::to_string)
                                .collect()
                        })
                        .unwrap_or_default(),
                });
            }
        }
        Ok(view)
    }
}

fn kv_fields(s: &str) -> HashMap<String, String> {
    s.split_whitespace()
        .filter_map(|tok| tok.split_once('='))
        .map(|(k, v)| (k.to_string(), v.to_string()))
        .collect()
}

/// Per-node Prometheus exposition for the control plane's `metrics`
/// verb — the per-node labels the single-process exposition cannot
/// carry.
pub fn render_cluster_prometheus(view: &ClusterView) -> String {
    let mut w = PromWriter::new();
    w.header("tmi_node_up", "Node liveness as seen by heartbeats (1 = serving set).", "gauge");
    for n in &view.nodes {
        w.int_sample("tmi_node_up", &[("node", &n.id)], u64::from(n.alive));
    }
    w.header("tmi_heartbeats_total", "Successful heartbeat probes per node.", "counter");
    for n in &view.nodes {
        w.int_sample("tmi_heartbeats_total", &[("node", &n.id)], n.beats);
    }
    w.header("tmi_missed_beats_total", "Missed heartbeat probes per node.", "counter");
    for n in &view.nodes {
        w.int_sample("tmi_missed_beats_total", &[("node", &n.id)], n.missed_total);
    }
    w.header(
        "tmi_replications_total",
        "Snapshot replication pushes installed per node.",
        "counter",
    );
    for n in &view.nodes {
        w.int_sample("tmi_replications_total", &[("node", &n.id)], n.replications);
    }
    w.header(
        "tmi_replication_failures_total",
        "Replication pushes refused or failed per node (retried).",
        "counter",
    );
    for n in &view.nodes {
        w.int_sample(
            "tmi_replication_failures_total",
            &[("node", &n.id)],
            n.replication_failures,
        );
    }
    w.header(
        "tmi_cluster_generation",
        "Registry manifest generation last replicated from.",
        "gauge",
    );
    w.int_sample("tmi_cluster_generation", &[], view.generation);
    w.finish()
}

/// Push one snapshot image to a node over the line protocol:
/// `replicate <route> <version> <infer> <len>` + raw bytes, then wait
/// for the node's verdict line. `Ok` is the node's `ok replicated ...`
/// reply; any transport failure or `err ...` reply is `Err`.
pub fn push_snapshot(
    addr: &str,
    route: &str,
    version: u64,
    infer: InferMode,
    image: &[u8],
    timeout: Duration,
) -> Result<String, String> {
    let sock = resolve(addr)?;
    let mut stream =
        TcpStream::connect_timeout(&sock, timeout).map_err(|e| format!("connect {addr}: {e}"))?;
    stream
        .set_write_timeout(Some(timeout))
        .and_then(|()| stream.set_read_timeout(Some(timeout)))
        .map_err(|e| format!("socket setup {addr}: {e}"))?;
    let header = format!("replicate {route} {version} {} {}\n", infer.name(), image.len());
    stream
        .write_all(header.as_bytes())
        .and_then(|()| stream.write_all(image))
        .map_err(|e| format!("send {addr}: {e}"))?;
    let mut reply = String::new();
    BufReader::new(stream)
        .read_line(&mut reply)
        .map_err(|e| format!("reply {addr}: {e}"))?;
    if reply.ends_with('\n') && reply.starts_with("ok ") {
        Ok(reply.trim_end().to_string())
    } else {
        Err(format!("node {addr} refused: {}", reply.trim_end()))
    }
}

/// One-line liveness probe.
pub fn ping(addr: &str, timeout: Duration) -> Result<String, String> {
    let sock = resolve(addr)?;
    let mut stream =
        TcpStream::connect_timeout(&sock, timeout).map_err(|e| format!("connect {addr}: {e}"))?;
    stream
        .set_write_timeout(Some(timeout))
        .and_then(|()| stream.set_read_timeout(Some(timeout)))
        .map_err(|e| format!("socket setup {addr}: {e}"))?;
    stream
        .write_all(b"ping\n")
        .map_err(|e| format!("send {addr}: {e}"))?;
    let mut reply = String::new();
    BufReader::new(stream)
        .read_line(&mut reply)
        .map_err(|e| format!("reply {addr}: {e}"))?;
    if reply.ends_with('\n') && reply.starts_with("ok ") {
        Ok(reply.trim_end().to_string())
    } else {
        Err(format!("bad pong from {addr}: {}", reply.trim_end()))
    }
}

fn resolve(addr: &str) -> Result<std::net::SocketAddr, String> {
    addr.to_socket_addrs()
        .map_err(|e| format!("resolve {addr}: {e}"))?
        .next()
        .ok_or_else(|| format!("resolve {addr}: no address"))
}

/// One replication source: the registry's published image for a route.
struct RouteSrc {
    infer: InferMode,
    version: u64,
    file: PathBuf,
    crc: u32,
}

/// The control plane. Heartbeats and replication run in
/// [`ControlPlane::run`] (or step-wise via [`ControlPlane::tick`]);
/// the shared [`ClusterView`] feeds [`serve_control`].
pub struct ControlPlane {
    cfg: ControlConfig,
    ring: Ring,
    view: Arc<Mutex<ClusterView>>,
    /// (node id, route) -> version last installed there.
    pushed: HashMap<(String, String), u64>,
    /// Replication sources from the registry manifest.
    sources: HashMap<String, RouteSrc>,
    gen_seen: Option<u64>,
    /// Nodes never yet seen alive don't journal `node_evict` — they
    /// were never admitted.
    ever_up: HashMap<String, bool>,
}

impl ControlPlane {
    /// Control plane over the given config (not yet heartbeating).
    pub fn new(cfg: ControlConfig) -> ControlPlane {
        let ids: Vec<&str> = cfg.nodes.iter().map(|n| n.id.as_str()).collect();
        let ring = Ring::with_vnodes(&ids, cfg.vnodes);
        let view = ClusterView {
            nodes: cfg
                .nodes
                .iter()
                .map(|n| NodeView {
                    id: n.id.clone(),
                    addr: n.addr.clone(),
                    // optimistic until the first probe: routes get
                    // owners immediately, and a wrong guess costs one
                    // failed push that retries after eviction
                    alive: true,
                    missed: 0,
                    missed_total: 0,
                    beats: 0,
                    replications: 0,
                    replication_failures: 0,
                })
                .collect(),
            routes: Vec::new(),
            generation: 0,
        };
        ControlPlane {
            cfg,
            ring,
            view: Arc::new(Mutex::new(view)),
            pushed: HashMap::new(),
            sources: HashMap::new(),
            gen_seen: None,
            ever_up: HashMap::new(),
        }
    }

    /// The shared view handle for [`serve_control`].
    pub fn shared_view(&self) -> Arc<Mutex<ClusterView>> {
        Arc::clone(&self.view)
    }

    /// A point-in-time copy of the cluster state.
    pub fn view(&self) -> ClusterView {
        self.view.lock().unwrap_or_else(PoisonError::into_inner).clone()
    }

    /// Heartbeat + replicate until `stop`, pacing by the configured
    /// heartbeat interval (checked in small sleeps so shutdown is
    /// prompt).
    pub fn run(&mut self, stop: &AtomicBool) {
        while !stop.load(Ordering::Relaxed) {
            let t0 = Instant::now();
            self.tick();
            while t0.elapsed() < self.cfg.heartbeat && !stop.load(Ordering::Relaxed) {
                std::thread::sleep(Duration::from_millis(10).min(self.cfg.heartbeat));
            }
        }
    }

    /// One control iteration: probe every node, refresh replication
    /// sources from the registry, push missing versions to owners.
    pub fn tick(&mut self) {
        self.probe_nodes();
        self.sync_registry();
        self.replicate();
    }

    fn probe_nodes(&mut self) {
        let mut view = self.view.lock().unwrap_or_else(PoisonError::into_inner);
        for node in view.nodes.iter_mut() {
            match ping(&node.addr, self.cfg.probe_timeout) {
                Ok(_) => {
                    node.beats += 1;
                    node.missed = 0;
                    let first_up = !self.ever_up.get(&node.id).copied().unwrap_or(false);
                    if !node.alive || first_up {
                        // first sighting or re-admission after eviction
                        journal().emit(EventKind::NodeUp {
                            node: node.id.clone(),
                        });
                        node.alive = true;
                        // its state is unknown — re-replicate everything
                        let id = node.id.clone();
                        self.pushed.retain(|(n, _), _| *n != id);
                    }
                    self.ever_up.insert(node.id.clone(), true);
                }
                Err(_) => {
                    node.missed += 1;
                    node.missed_total += 1;
                    if node.alive {
                        journal().emit(EventKind::NodeDown {
                            node: node.id.clone(),
                            missed: node.missed,
                        });
                        if node.missed >= u64::from(self.cfg.miss_threshold) {
                            node.alive = false;
                            if self.ever_up.get(&node.id).copied().unwrap_or(false) {
                                journal().emit(EventKind::NodeEvict {
                                    node: node.id.clone(),
                                    missed: node.missed,
                                });
                            }
                        }
                    }
                }
            }
        }
    }

    fn sync_registry(&mut self) {
        let dir = self.cfg.registry_dir.clone();
        let gen = read_generation(&dir);
        if gen.is_none() || gen == self.gen_seen {
            return;
        }
        let Ok(registry) = Registry::open(&dir, crate::registry::store::DEFAULT_RETAIN) else {
            return; // transient manifest trouble: keep old sources
        };
        self.sources.clear();
        for (name, entry) in registry.routes() {
            let v = entry
                .versions
                .iter()
                .find(|v| v.version == entry.published)
                .or_else(|| entry.versions.last());
            if let Some(v) = v {
                self.sources.insert(
                    name.to_string(),
                    RouteSrc {
                        infer: entry.infer,
                        version: v.version,
                        file: dir.join(&v.file),
                        crc: v.crc32,
                    },
                );
            }
        }
        self.gen_seen = Some(registry.generation());
        let mut view = self.view.lock().unwrap_or_else(PoisonError::into_inner);
        view.generation = registry.generation();
    }

    fn replicate(&mut self) {
        let (alive_ids, addr_of): (Vec<String>, HashMap<String, String>) = {
            let view = self.view.lock().unwrap_or_else(PoisonError::into_inner);
            (
                view.nodes.iter().filter(|n| n.alive).map(|n| n.id.clone()).collect(),
                view.nodes.iter().map(|n| (n.id.clone(), n.addr.clone())).collect(),
            )
        };
        let mut placements: Vec<RouteView> = Vec::new();
        let mut route_names: Vec<&String> = self.sources.keys().collect();
        route_names.sort();
        for name in route_names {
            let src = &self.sources[name];
            // walk the full ring order, keep the first `replicas`
            // alive owners — eviction slides ownership to the next
            // replica instead of reshuffling the ring
            let owners: Vec<String> = self
                .ring
                .replicas(name, self.ring.len())
                .into_iter()
                .filter(|id| alive_ids.iter().any(|a| a == id))
                .take(self.cfg.replicas.max(1))
                .map(str::to_string)
                .collect();
            for owner in &owners {
                let key = (owner.clone(), name.clone());
                if self.pushed.get(&key) == Some(&src.version) {
                    continue;
                }
                let Some(addr) = addr_of.get(owner) else { continue };
                match self.push_route(addr, name, src) {
                    Ok(()) => {
                        self.pushed.insert(key, src.version);
                        journal().emit(EventKind::Replicate {
                            node: owner.clone(),
                            route: name.clone(),
                            version: src.version,
                        });
                        self.bump(owner, |n| n.replications += 1);
                    }
                    Err(_) => self.bump(owner, |n| n.replication_failures += 1),
                }
            }
            placements.push(RouteView {
                name: name.clone(),
                version: src.version,
                owners,
            });
        }
        let mut view = self.view.lock().unwrap_or_else(PoisonError::into_inner);
        view.routes = placements;
    }

    fn push_route(&self, addr: &str, route: &str, src: &RouteSrc) -> Result<(), String> {
        let image = std::fs::read(&src.file).map_err(|e| format!("read {:?}: {e}", src.file))?;
        // pre-flight the registry's own digest: a damaged source file
        // must not travel — the node would refuse it anyway, but this
        // keeps the failure local and the reason exact
        if crc32(&image) != src.crc {
            return Err(format!("source image for {route} fails its manifest CRC"));
        }
        push_snapshot(addr, route, src.version, src.infer, &image, self.cfg.push_timeout)
            .map(|_| ())
    }

    fn bump(&self, node_id: &str, f: impl FnOnce(&mut NodeView)) {
        let mut view = self.view.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(n) = view.nodes.iter_mut().find(|n| n.id == node_id) {
            f(n);
        }
    }
}

/// Serve the control-plane verbs — `cluster`, `ping`, `metrics` — on a
/// listener. Runs until `stop`; connections are handled inline (a
/// reply is one render and one write, like the metrics scrape loop).
pub fn serve_control(
    listener: TcpListener,
    view: Arc<Mutex<ClusterView>>,
    stop: Arc<AtomicBool>,
) -> std::io::Result<()> {
    listener.set_nonblocking(true)?;
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _addr)) => {
                let view = Arc::clone(&view);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    let _ = control_conn(stream, &view, &stop);
                });
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

fn control_conn(
    stream: TcpStream,
    view: &Mutex<ClusterView>,
    stop: &AtomicBool,
) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(100)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut stream = stream;
    let mut line = String::new();
    loop {
        line.clear();
        let n = loop {
            match reader.read_line(&mut line) {
                Ok(n) => break n,
                Err(ref e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    if stop.load(Ordering::Relaxed) {
                        return Ok(());
                    }
                }
                Err(e) => return Err(e),
            }
        };
        if n == 0 || !line.ends_with('\n') {
            return Ok(());
        }
        let snapshot = || view.lock().unwrap_or_else(PoisonError::into_inner).clone();
        let reply = match line.trim() {
            "cluster" => snapshot().to_wire(),
            "ping" => {
                let v = snapshot();
                format!("ok pong control nodes={} alive={}\n", v.nodes.len(), v.alive())
            }
            "metrics" => render_cluster_prometheus(&snapshot()),
            other => format!("err unknown verb '{}': control serves cluster|ping|metrics\n", {
                let mut o = other.to_string();
                o.truncate(32);
                o
            }),
        };
        stream.write_all(reply.as_bytes())?;
    }
}

/// Fetch and parse a `cluster` reply — the router's membership poll.
pub fn fetch_cluster_view(addr: &str, timeout: Duration) -> Result<ClusterView, String> {
    let sock = resolve(addr)?;
    let mut stream =
        TcpStream::connect_timeout(&sock, timeout).map_err(|e| format!("connect {addr}: {e}"))?;
    stream
        .set_write_timeout(Some(timeout))
        .and_then(|()| stream.set_read_timeout(Some(timeout)))
        .map_err(|e| format!("socket setup {addr}: {e}"))?;
    stream
        .write_all(b"cluster\n")
        .map_err(|e| format!("send {addr}: {e}"))?;
    let mut reader = BufReader::new(stream);
    let mut header = String::new();
    reader
        .read_line(&mut header)
        .map_err(|e| format!("reply {addr}: {e}"))?;
    if !header.ends_with('\n') || !header.starts_with("ok ") {
        return Err(format!("bad cluster reply from {addr}: {}", header.trim_end()));
    }
    let fields = kv_fields(header.trim().strip_prefix("ok ").unwrap_or(""));
    let count = |k: &str| fields.get(k).and_then(|v| v.parse::<usize>().ok()).unwrap_or(0);
    let expect = count("nodes") + count("routes");
    let mut lines = Vec::with_capacity(expect);
    for _ in 0..expect {
        let mut l = String::new();
        reader
            .read_line(&mut l)
            .map_err(|e| format!("reply {addr}: {e}"))?;
        if !l.ends_with('\n') {
            return Err(format!("truncated cluster reply from {addr}"));
        }
        lines.push(l);
    }
    ClusterView::from_wire(&header, &lines)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_spec_parses_and_rejects() {
        let n = NodeSpec::parse("n1@127.0.0.1:7101").unwrap();
        assert_eq!((n.id.as_str(), n.addr.as_str()), ("n1", "127.0.0.1:7101"));
        assert!(NodeSpec::parse("no-at-sign").is_err());
        assert!(NodeSpec::parse("@addr").is_err());
        assert!(NodeSpec::parse("id@").is_err());
        let list = NodeSpec::parse_list("a@x:1, b@y:2").unwrap();
        assert_eq!(list.len(), 2);
        assert!(NodeSpec::parse_list("").is_err());
    }

    #[test]
    fn cluster_view_roundtrips_through_the_wire_form() {
        let view = ClusterView {
            nodes: vec![
                NodeView {
                    id: "n1".into(),
                    addr: "127.0.0.1:7101".into(),
                    alive: true,
                    missed: 0,
                    missed_total: 2,
                    beats: 40,
                    replications: 3,
                    replication_failures: 1,
                },
                NodeView {
                    id: "n2".into(),
                    addr: "127.0.0.1:7102".into(),
                    alive: false,
                    missed: 5,
                    missed_total: 5,
                    beats: 12,
                    replications: 2,
                    replication_failures: 0,
                },
            ],
            routes: vec![RouteView {
                name: "cpu".into(),
                version: 4,
                owners: vec!["n1".into()],
            }],
            generation: 9,
        };
        let wire = view.to_wire();
        assert!(wire.starts_with("ok nodes=2 alive=1 routes=1 generation=9\n"));
        let mut lines = wire.lines();
        let header = lines.next().unwrap().to_string();
        let body: Vec<String> = lines.map(|l| format!("{l}\n")).collect();
        let parsed = ClusterView::from_wire(&header, &body).unwrap();
        assert_eq!(parsed.generation, 9);
        assert_eq!(parsed.nodes.len(), 2);
        assert_eq!(parsed.nodes[0].id, "n1");
        assert!(parsed.nodes[0].alive);
        assert!(!parsed.nodes[1].alive);
        assert_eq!(parsed.routes[0].owners, vec!["n1".to_string()]);
        assert_eq!(parsed.routes[0].version, 4);
    }

    #[test]
    fn probes_evict_after_threshold_and_track_counters() {
        // nothing listens on this port: every probe misses
        let mut cfg = ControlConfig::new(
            vec![NodeSpec::parse("dead@127.0.0.1:1").unwrap()],
            std::env::temp_dir().join("tmi-ctl-none"),
        );
        cfg.probe_timeout = Duration::from_millis(50);
        cfg.miss_threshold = 2;
        let mut plane = ControlPlane::new(cfg);
        plane.probe_nodes();
        let v = plane.view();
        assert!(v.nodes[0].alive, "one miss must not evict at threshold 2");
        assert_eq!(v.nodes[0].missed, 1);
        plane.probe_nodes();
        let v = plane.view();
        assert!(!v.nodes[0].alive, "threshold crossed");
        assert_eq!(v.nodes[0].missed_total, 2);
        assert_eq!(v.alive(), 0);
    }

    #[test]
    fn prometheus_exposition_carries_node_labels() {
        let mut view = ClusterView::default();
        view.nodes.push(NodeView {
            id: "n1".into(),
            addr: "x".into(),
            alive: true,
            missed: 0,
            missed_total: 7,
            beats: 3,
            replications: 2,
            replication_failures: 1,
        });
        let text = render_cluster_prometheus(&view);
        assert!(text.contains("tmi_node_up{node=\"n1\"} 1"));
        assert!(text.contains("tmi_missed_beats_total{node=\"n1\"} 7"));
        assert!(text.contains("tmi_replications_total{node=\"n1\"} 2"));
        assert!(text.contains("tmi_replication_failures_total{node=\"n1\"} 1"));
        assert!(text.ends_with("# EOF\n"));
    }
}
