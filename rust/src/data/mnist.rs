//! MNIST / Fashion-MNIST loading: IDX format parser with synthetic
//! fallback.
//!
//! Looks for the standard four files (`train-images-idx3-ubyte`, etc.,
//! uncompressed) under a data directory. If absent, falls back to the
//! calibrated synthetic generator — every experiment runs either way
//! (DESIGN.md §Substitutions).

use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::data::binarize::binarize_images;
use crate::data::dataset::Dataset;
use crate::data::synth::{self, ImageStyle};

/// Parse an IDX images file (magic 0x00000803).
pub fn parse_idx_images(bytes: &[u8]) -> Result<Vec<Vec<u8>>> {
    ensure!(bytes.len() >= 16, "idx images: truncated header");
    let magic = u32::from_be_bytes(bytes[0..4].try_into().unwrap());
    ensure!(magic == 0x0000_0803, "idx images: bad magic {magic:#x}");
    let count = u32::from_be_bytes(bytes[4..8].try_into().unwrap()) as usize;
    let rows = u32::from_be_bytes(bytes[8..12].try_into().unwrap()) as usize;
    let cols = u32::from_be_bytes(bytes[12..16].try_into().unwrap()) as usize;
    let pixels = rows * cols;
    ensure!(
        bytes.len() == 16 + count * pixels,
        "idx images: size mismatch ({} != {})",
        bytes.len(),
        16 + count * pixels
    );
    Ok((0..count)
        .map(|i| bytes[16 + i * pixels..16 + (i + 1) * pixels].to_vec())
        .collect())
}

/// Parse an IDX labels file (magic 0x00000801).
pub fn parse_idx_labels(bytes: &[u8]) -> Result<Vec<usize>> {
    ensure!(bytes.len() >= 8, "idx labels: truncated header");
    let magic = u32::from_be_bytes(bytes[0..4].try_into().unwrap());
    ensure!(magic == 0x0000_0801, "idx labels: bad magic {magic:#x}");
    let count = u32::from_be_bytes(bytes[4..8].try_into().unwrap()) as usize;
    ensure!(bytes.len() == 8 + count, "idx labels: size mismatch");
    Ok(bytes[8..].iter().map(|&b| b as usize).collect())
}

/// Which split to load.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    /// The training split.
    Train,
    /// The held-out test split.
    Test,
}

/// Load real IDX files from `dir` and binarize with `levels` thresholds.
pub fn load_idx(dir: &Path, split: Split, levels: usize) -> Result<Dataset> {
    let (img_name, lbl_name) = match split {
        Split::Train => ("train-images-idx3-ubyte", "train-labels-idx1-ubyte"),
        Split::Test => ("t10k-images-idx3-ubyte", "t10k-labels-idx1-ubyte"),
    };
    let images = std::fs::read(dir.join(img_name))
        .with_context(|| format!("reading {img_name}"))?;
    let labels = std::fs::read(dir.join(lbl_name))
        .with_context(|| format!("reading {lbl_name}"))?;
    let images = parse_idx_images(&images)?;
    let labels = parse_idx_labels(&labels)?;
    ensure!(images.len() == labels.len(), "images/labels count mismatch");
    if let Some(&bad) = labels.iter().find(|&&y| y > 9) {
        bail!("label {bad} out of range for a 10-class set");
    }
    let features = levels * images[0].len();
    let rows = binarize_images(&images, levels);
    Ok(Dataset::from_rows(
        format!("idx-{}-L{levels}", dir.display()),
        features,
        10,
        &rows,
        labels,
    ))
}

/// Load real data if `dir` contains IDX files, else synthesize.
///
/// `style` selects the synthetic profile (Digits ≙ MNIST, Fashion ≙
/// F-MNIST); `samples` caps the returned size either way (the bench
/// harness uses fixed subsets for comparable epoch timings).
pub fn load_or_synthesize(
    dir: Option<&Path>,
    style: ImageStyle,
    split: Split,
    levels: usize,
    samples: usize,
    seed: u64,
) -> Dataset {
    if let Some(dir) = dir {
        if let Ok(ds) = load_idx(dir, split, levels) {
            return ds.take(samples);
        }
    }
    // disjoint sample streams for train/test from one prototype set
    let (extra, skip) = match split {
        Split::Train => (0, 0),
        Split::Test => (samples, samples),
    };
    let _ = extra;
    let all = synth::image_dataset(style, 10, samples + skip, levels, seed);
    all.slice(skip, skip + samples)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idx_images_bytes(imgs: &[Vec<u8>], rows: usize, cols: usize) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(&0x0000_0803u32.to_be_bytes());
        b.extend_from_slice(&(imgs.len() as u32).to_be_bytes());
        b.extend_from_slice(&(rows as u32).to_be_bytes());
        b.extend_from_slice(&(cols as u32).to_be_bytes());
        for im in imgs {
            b.extend_from_slice(im);
        }
        b
    }

    fn idx_labels_bytes(labels: &[u8]) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(&0x0000_0801u32.to_be_bytes());
        b.extend_from_slice(&(labels.len() as u32).to_be_bytes());
        b.extend_from_slice(labels);
        b
    }

    #[test]
    fn parses_crafted_idx_images() {
        let imgs = vec![vec![1u8, 2, 3, 4], vec![5, 6, 7, 8]];
        let bytes = idx_images_bytes(&imgs, 2, 2);
        assert_eq!(parse_idx_images(&bytes).unwrap(), imgs);
    }

    #[test]
    fn parses_crafted_idx_labels() {
        let bytes = idx_labels_bytes(&[3, 1, 4]);
        assert_eq!(parse_idx_labels(&bytes).unwrap(), vec![3, 1, 4]);
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        let imgs = vec![vec![1u8, 2, 3, 4]];
        let mut bytes = idx_images_bytes(&imgs, 2, 2);
        bytes[3] = 0x99;
        assert!(parse_idx_images(&bytes).is_err());
        let bytes = idx_images_bytes(&imgs, 2, 2);
        assert!(parse_idx_images(&bytes[..bytes.len() - 1]).is_err());
        assert!(parse_idx_labels(&[0, 0]).is_err());
    }

    #[test]
    fn load_idx_roundtrip_via_tempdir() {
        let dir = std::env::temp_dir().join(format!("tmi-idx-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let imgs = vec![vec![0u8; 4], vec![255u8; 4]];
        std::fs::write(
            dir.join("train-images-idx3-ubyte"),
            idx_images_bytes(&imgs, 2, 2),
        )
        .unwrap();
        std::fs::write(dir.join("train-labels-idx1-ubyte"), idx_labels_bytes(&[0, 7]))
            .unwrap();
        let ds = load_idx(&dir, Split::Train, 2).unwrap();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.features, 8);
        assert_eq!(ds.label(1), 7);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fallback_synthesizes_disjoint_splits() {
        let train = load_or_synthesize(None, ImageStyle::Digits, Split::Train, 1, 50, 9);
        let test = load_or_synthesize(None, ImageStyle::Digits, Split::Test, 1, 50, 9);
        assert_eq!(train.len(), 50);
        assert_eq!(test.len(), 50);
        // same prototypes, different samples
        let same = (0..50)
            .filter(|&i| train.literals(i) == test.literals(i))
            .count();
        assert!(same < 5, "{same} identical samples across splits");
    }
}
