//! Datasets: representation, binarization, loaders, and calibrated
//! synthetic generators.
//!
//! The paper evaluates on MNIST (M1–M4), Fashion-MNIST (F1–F4) and IMDb
//! (I1–I4). Real MNIST/F-MNIST IDX files are loaded when present under a
//! data directory; otherwise the [`synth`] generators produce structured
//! stand-ins calibrated to the paper's reported statistics (mean clause
//! length ≈58 on M1, ≈116 on IMDb; see DESIGN.md §Substitutions). The
//! speedup experiments depend on (features, clauses, literal/clause
//! sparsity), not on label semantics, so the substitution preserves the
//! measured behaviour.

pub mod binarize;
pub mod dataset;
pub mod imdb;
pub mod mnist;
pub mod sparse;
pub mod synth;

pub use binarize::binarize_images;
pub use dataset::Dataset;
pub use sparse::{SparseDataset, SparseSample};
