//! Labelled Boolean dataset with precomputed literal vectors.

use crate::util::{BitVec, Rng};

/// A labelled dataset. Each sample is stored as its full **literal
/// vector** of length `2o` (`[x, ¬x]`), which is what every evaluator
/// consumes — the negated half is precomputed once at load time.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Human-readable dataset name (appears in bench reports).
    pub name: String,
    /// Number of raw boolean features per sample.
    pub features: usize,
    /// Number of label classes.
    pub classes: usize,
    samples: Vec<BitVec>,
    labels: Vec<usize>,
}

impl Dataset {
    /// Build from raw feature rows (`rows[i].len() == features`).
    pub fn from_rows(
        name: impl Into<String>,
        features: usize,
        classes: usize,
        rows: &[Vec<bool>],
        labels: Vec<usize>,
    ) -> Self {
        assert_eq!(rows.len(), labels.len());
        let samples = rows
            .iter()
            .map(|row| {
                assert_eq!(row.len(), features);
                Self::literals_from_bools(row)
            })
            .collect();
        for &y in &labels {
            assert!(y < classes, "label {y} out of range");
        }
        Dataset {
            name: name.into(),
            features,
            classes,
            samples,
            labels,
        }
    }

    /// Build from already-materialized `[x, ¬x]` literal vectors
    /// (the sparse→dense converter; see
    /// [`crate::data::SparseDataset::to_dense`]).
    pub fn from_literal_vecs(
        name: impl Into<String>,
        features: usize,
        classes: usize,
        samples: Vec<BitVec>,
        labels: Vec<usize>,
    ) -> Self {
        assert_eq!(samples.len(), labels.len());
        for s in &samples {
            assert_eq!(s.len(), 2 * features, "literal width mismatch");
        }
        for &y in &labels {
            assert!(y < classes, "label {y} out of range");
        }
        Dataset {
            name: name.into(),
            features,
            classes,
            samples,
            labels,
        }
    }

    /// Sparsify into the k-hot representation the O(nnz) sparse-delta
    /// engine scores natively.
    pub fn to_sparse(&self) -> crate::data::SparseDataset {
        crate::data::SparseDataset::from_dense(self)
    }

    /// `[x, ¬x]` literal vector from a feature row.
    pub fn literals_from_bools(row: &[bool]) -> BitVec {
        let o = row.len();
        let mut lits = BitVec::zeros(2 * o);
        for (k, &b) in row.iter().enumerate() {
            if b {
                lits.set(k);
            } else {
                lits.set(o + k);
            }
        }
        lits
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// True if the dataset holds no samples.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    #[inline]
    /// The literal vector (`[x, ¬x]`, length `2 × features`) of sample `i`.
    pub fn literals(&self, i: usize) -> &BitVec {
        &self.samples[i]
    }

    /// All literal vectors as one slice — the shape batch scorers
    /// ([`crate::engine::BatchScorer`]) consume without copying.
    #[inline]
    pub fn all_literals(&self) -> &[BitVec] {
        &self.samples
    }

    #[inline]
    /// The label of sample `i`.
    pub fn label(&self, i: usize) -> usize {
        self.labels[i]
    }

    /// Iterate `(literals, label)` pairs in index order.
    pub fn iter(&self) -> impl Iterator<Item = (&BitVec, usize)> {
        self.samples.iter().zip(self.labels.iter().copied())
    }

    /// Iterate in a caller-provided order (epoch shuffling).
    pub fn iter_order<'a>(
        &'a self,
        order: &'a [usize],
    ) -> impl Iterator<Item = (&'a BitVec, usize)> + 'a {
        order.iter().map(move |&i| (&self.samples[i], self.labels[i]))
    }

    /// Shuffled index order for one epoch.
    pub fn epoch_order(&self, rng: &mut Rng) -> Vec<usize> {
        let mut order: Vec<usize> = (0..self.len()).collect();
        rng.shuffle(&mut order);
        order
    }

    /// First `n` samples as a new dataset (bench subsets).
    pub fn take(&self, n: usize) -> Dataset {
        self.slice(0, n)
    }

    /// Samples `[start, end)` as a new dataset (train/test splits).
    pub fn slice(&self, start: usize, end: usize) -> Dataset {
        let end = end.min(self.len());
        let start = start.min(end);
        Dataset {
            name: self.name.clone(),
            features: self.features,
            classes: self.classes,
            samples: self.samples[start..end].to_vec(),
            labels: self.labels[start..end].to_vec(),
        }
    }

    /// Fraction of literals that are FALSE per sample — the quantity the
    /// indexed walk's work is proportional to. Always exactly 0.5 for
    /// `[x, ¬x]` literal vectors; kept for datasets built from raw rows.
    pub fn mean_false_literal_fraction(&self) -> f64 {
        if self.is_empty() {
            return 0.0;
        }
        let total: usize = self
            .samples
            .iter()
            .map(|s| s.len() - s.count_ones())
            .sum();
        total as f64 / (self.samples.len() * 2 * self.features) as f64
    }

    /// Fraction of raw *features* set (document density for BoW data).
    pub fn mean_feature_density(&self) -> f64 {
        if self.is_empty() {
            return 0.0;
        }
        let total: usize = self
            .samples
            .iter()
            .map(|s| (0..self.features).filter(|&k| s.get(k)).count())
            .sum();
        total as f64 / (self.samples.len() * self.features) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset::from_rows(
            "t",
            3,
            2,
            &[
                vec![true, false, true],
                vec![false, false, false],
            ],
            vec![0, 1],
        )
    }

    #[test]
    fn literal_layout_is_x_then_not_x() {
        let d = tiny();
        let l = d.literals(0);
        assert_eq!(l.len(), 6);
        assert!(l.get(0) && !l.get(1) && l.get(2)); // x
        assert!(!l.get(3) && l.get(4) && !l.get(5)); // ¬x
    }

    #[test]
    fn exactly_half_literals_true() {
        let d = tiny();
        for i in 0..d.len() {
            assert_eq!(d.literals(i).count_ones(), 3);
        }
        assert!((d.mean_false_literal_fraction() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn feature_density() {
        let d = tiny();
        // 2 of 3 + 0 of 3 = 2/6
        assert!((d.mean_feature_density() - 2.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn epoch_order_is_permutation() {
        let d = tiny();
        let mut rng = Rng::new(1);
        let ord = d.epoch_order(&mut rng);
        let mut s = ord.clone();
        s.sort_unstable();
        assert_eq!(s, vec![0, 1]);
    }

    #[test]
    fn take_truncates() {
        let d = tiny();
        assert_eq!(d.take(1).len(), 1);
        assert_eq!(d.take(10).len(), 2);
    }

    #[test]
    #[should_panic]
    fn label_out_of_range_panics() {
        Dataset::from_rows("t", 1, 2, &[vec![true]], vec![5]);
    }
}
