//! Sparse sample representation for k-hot workloads.
//!
//! High-dimensional bag-of-words inputs (IMDb binarized BoW at 5k–20k
//! features) are ≥95% zeros, yet [`crate::data::Dataset`] stores every
//! sample as a dense `[x, ¬x]` literal vector and every evaluator walks
//! it feature by feature. A [`SparseSample`] stores only the *set*
//! feature ids — the representation the O(nnz) sparse-delta engine
//! ([`crate::engine::SparseEngine`]) scores directly, and what the
//! libsvm-lite IMDb loader ([`crate::data::imdb`]) parses without ever
//! densifying. Dense↔sparse converters keep both worlds exact: a
//! round-trip through either direction reproduces the same literal
//! vectors bit for bit.

use crate::data::dataset::Dataset;
use crate::util::BitVec;

/// One k-hot sample: the sorted, deduplicated ids of its set features.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SparseSample {
    features: usize,
    /// Strictly increasing set-feature ids, all `< features`.
    set: Vec<u32>,
}

impl SparseSample {
    /// Build from raw indices (sorted + deduplicated here). Panics on
    /// out-of-range ids.
    pub fn new(features: usize, mut set: Vec<u32>) -> Self {
        set.sort_unstable();
        set.dedup();
        if let Some(&last) = set.last() {
            assert!((last as usize) < features, "feature id {last} >= {features}");
        }
        SparseSample { features, set }
    }

    /// Extract the set features of a dense `[x, ¬x]` literal vector
    /// (reads the positive half; the negated half must be its exact
    /// complement, which every [`Dataset`] sample satisfies).
    pub fn from_literals(literals: &BitVec) -> Self {
        let o = literals.len() / 2;
        debug_assert_eq!(literals.len(), 2 * o);
        debug_assert!(
            (0..o).all(|k| literals.get(k) != literals.get(o + k)),
            "literal vector is not complement-structured [x, ¬x]"
        );
        let set = literals
            .iter_ones()
            .take_while(|&k| k < o)
            .map(|k| k as u32)
            .collect();
        SparseSample { features: o, set }
    }

    /// Materialize the dense `[x, ¬x]` literal vector.
    pub fn to_literals(&self) -> BitVec {
        let o = self.features;
        let mut lits = BitVec::zeros(2 * o);
        let mut next = self.set.iter().peekable();
        for k in 0..o {
            if next.peek().is_some_and(|&&s| s as usize == k) {
                lits.set(k);
                next.next();
            } else {
                lits.set(o + k);
            }
        }
        lits
    }

    #[inline]
    /// Number of raw boolean features this sample was built for.
    pub fn features(&self) -> usize {
        self.features
    }

    /// The sorted set-feature ids — what the sparse walk iterates.
    #[inline]
    pub fn ones(&self) -> &[u32] {
        &self.set
    }

    /// Number of set features.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.set.len()
    }

    /// Fraction of features set.
    pub fn density(&self) -> f64 {
        if self.features == 0 {
            0.0
        } else {
            self.set.len() as f64 / self.features as f64
        }
    }
}

/// A labelled k-hot dataset: the sparse twin of [`Dataset`].
#[derive(Clone, Debug)]
pub struct SparseDataset {
    /// Human-readable dataset name (appears in bench reports).
    pub name: String,
    /// Number of raw boolean features per sample.
    pub features: usize,
    /// Number of label classes.
    pub classes: usize,
    samples: Vec<SparseSample>,
    labels: Vec<usize>,
}

impl SparseDataset {
    /// Build a k-hot dataset from per-sample set-feature lists.
    pub fn new(
        name: impl Into<String>,
        features: usize,
        classes: usize,
        samples: Vec<SparseSample>,
        labels: Vec<usize>,
    ) -> Self {
        assert_eq!(samples.len(), labels.len());
        for s in &samples {
            assert_eq!(s.features(), features, "sample width mismatch");
        }
        for &y in &labels {
            assert!(y < classes, "label {y} out of range");
        }
        SparseDataset {
            name: name.into(),
            features,
            classes,
            samples,
            labels,
        }
    }

    /// Sparsify a dense dataset (exact: `to_dense` round-trips).
    pub fn from_dense(ds: &Dataset) -> Self {
        let samples = (0..ds.len())
            .map(|i| SparseSample::from_literals(ds.literals(i)))
            .collect();
        SparseDataset {
            name: ds.name.clone(),
            features: ds.features,
            classes: ds.classes,
            samples,
            labels: (0..ds.len()).map(|i| ds.label(i)).collect(),
        }
    }

    /// Densify into the `[x, ¬x]` literal representation.
    pub fn to_dense(&self) -> Dataset {
        Dataset::from_literal_vecs(
            self.name.clone(),
            self.features,
            self.classes,
            self.samples.iter().map(SparseSample::to_literals).collect(),
            self.labels.clone(),
        )
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// True if the dataset holds no samples.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    #[inline]
    /// The k-hot sample `i`.
    pub fn sample(&self, i: usize) -> &SparseSample {
        &self.samples[i]
    }

    /// All samples as one slice — the shape the sparse batch scorer
    /// consumes without copying.
    #[inline]
    pub fn all_samples(&self) -> &[SparseSample] {
        &self.samples
    }

    #[inline]
    /// The label of sample `i`.
    pub fn label(&self, i: usize) -> usize {
        self.labels[i]
    }

    /// Iterate `(sample, label)` pairs in index order.
    pub fn iter(&self) -> impl Iterator<Item = (&SparseSample, usize)> {
        self.samples.iter().zip(self.labels.iter().copied())
    }

    /// First `n` samples as a new dataset (bench subsets).
    pub fn take(&self, n: usize) -> SparseDataset {
        let n = n.min(self.len());
        SparseDataset {
            name: self.name.clone(),
            features: self.features,
            classes: self.classes,
            samples: self.samples[..n].to_vec(),
            labels: self.labels[..n].to_vec(),
        }
    }

    /// Mean fraction of features set — the quantity the sparse walk's
    /// work is proportional to (and what the auto-selection heuristic
    /// compares against its threshold).
    pub fn mean_density(&self) -> f64 {
        if self.is_empty() {
            return 0.0;
        }
        let nnz: usize = self.samples.iter().map(SparseSample::nnz).sum();
        nnz as f64 / (self.samples.len() * self.features) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_sorts_and_dedupes() {
        let s = SparseSample::new(10, vec![7, 2, 2, 5, 7]);
        assert_eq!(s.ones(), &[2, 5, 7]);
        assert_eq!(s.nnz(), 3);
        assert!((s.density() - 0.3).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = ">= 4")]
    fn sample_rejects_out_of_range() {
        SparseSample::new(4, vec![1, 4]);
    }

    #[test]
    fn literal_roundtrip_is_exact() {
        let s = SparseSample::new(6, vec![0, 3, 5]);
        let lits = s.to_literals();
        assert_eq!(lits.len(), 12);
        // positive half: exactly {0, 3, 5}; negated half: the complement
        for k in 0..6 {
            let on = [0usize, 3, 5].contains(&k);
            assert_eq!(lits.get(k), on, "x{k}");
            assert_eq!(lits.get(6 + k), !on, "¬x{k}");
        }
        assert_eq!(SparseSample::from_literals(&lits), s);
    }

    #[test]
    fn empty_and_full_samples() {
        let empty = SparseSample::new(5, vec![]);
        let lits = empty.to_literals();
        assert_eq!(lits.count_ones_prefix(5), 0);
        assert_eq!(lits.count_ones(), 5); // all negated literals set
        let full = SparseSample::new(5, (0..5).collect());
        assert_eq!(full.to_literals().count_ones_prefix(5), 5);
    }

    #[test]
    fn dense_sparse_dense_roundtrip() {
        let ds = Dataset::from_rows(
            "t",
            4,
            2,
            &[
                vec![true, false, true, false],
                vec![false, false, false, false],
                vec![true, true, true, true],
            ],
            vec![0, 1, 0],
        );
        let sp = SparseDataset::from_dense(&ds);
        assert_eq!(sp.len(), 3);
        assert_eq!(sp.sample(0).ones(), &[0, 2]);
        assert_eq!(sp.sample(1).nnz(), 0);
        assert_eq!(sp.label(1), 1);
        let back = sp.to_dense();
        for i in 0..3 {
            assert_eq!(back.literals(i), ds.literals(i), "sample {i}");
            assert_eq!(back.label(i), ds.label(i));
        }
    }

    #[test]
    fn mean_density() {
        let sp = SparseDataset::new(
            "t",
            10,
            2,
            vec![
                SparseSample::new(10, vec![1]),
                SparseSample::new(10, vec![1, 2, 3]),
            ],
            vec![0, 1],
        );
        assert!((sp.mean_density() - 0.2).abs() < 1e-12);
    }
}
