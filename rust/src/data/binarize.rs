//! k-threshold grey-level binarization (paper §4).
//!
//! M1/F1 use one threshold per pixel (784 features); M2–M4 / F2–F4 use
//! 2–4 evenly-spaced thresholds, giving 1568 / 2352 / 3136 features.
//! Layout is level-major: feature `g * pixels + p` is
//! `image[p] >= threshold(g)` — the same unary ("thermometer") code the
//! TM literature uses.

/// Threshold for grey level `g` of `levels` (1-based spacing over 0..=255).
#[inline]
pub fn threshold(g: usize, levels: usize) -> u8 {
    (((g + 1) * 256) / (levels + 1)) as u8
}

/// Binarize one image into `levels * pixels` booleans.
pub fn binarize_image(image: &[u8], levels: usize) -> Vec<bool> {
    let mut out = Vec::with_capacity(levels * image.len());
    for g in 0..levels {
        let t = threshold(g, levels);
        out.extend(image.iter().map(|&p| p >= t));
    }
    out
}

/// Binarize a batch of images.
pub fn binarize_images(images: &[Vec<u8>], levels: usize) -> Vec<Vec<bool>> {
    images.iter().map(|im| binarize_image(im, levels)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thresholds_are_evenly_spaced() {
        assert_eq!(threshold(0, 1), 128);
        assert_eq!(threshold(0, 3), 64);
        assert_eq!(threshold(1, 3), 128);
        assert_eq!(threshold(2, 3), 192);
    }

    #[test]
    fn single_level_is_simple_threshold() {
        let img = vec![0u8, 127, 128, 255];
        let b = binarize_image(&img, 1);
        assert_eq!(b, vec![false, false, true, true]);
    }

    #[test]
    fn feature_count_scales_with_levels() {
        let img = vec![100u8; 784];
        for levels in 1..=4 {
            assert_eq!(binarize_image(&img, levels).len(), levels * 784);
        }
    }

    #[test]
    fn thermometer_property_is_monotone() {
        // if a pixel clears level g, it clears all lower levels
        let img: Vec<u8> = (0..=255).step_by(5).map(|v| v as u8).collect();
        let levels = 4;
        let bits = binarize_image(&img, levels);
        let pixels = img.len();
        for p in 0..pixels {
            for g in 1..levels {
                if bits[g * pixels + p] {
                    assert!(bits[(g - 1) * pixels + p], "pixel {p} level {g}");
                }
            }
        }
    }

    #[test]
    fn batch_matches_single() {
        let imgs = vec![vec![10u8, 200], vec![255u8, 0]];
        let batch = binarize_images(&imgs, 2);
        assert_eq!(batch[0], binarize_image(&imgs[0], 2));
        assert_eq!(batch[1], binarize_image(&imgs[1], 2));
    }
}
