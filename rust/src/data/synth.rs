//! Calibrated synthetic generators (DESIGN.md §Substitutions).
//!
//! Real MNIST / Fashion-MNIST / IMDb are not bundled with this
//! repository. The speedup experiments measure *evaluation mechanics* —
//! work per sample as a function of features `o`, clauses `n`, literal
//! sparsity and learned clause length — so the generators below are
//! designed to match those statistics rather than the label semantics:
//!
//! * [`ImageStyle::Digits`] — sparse stroke images (≈19% ink, like
//!   MNIST): each class is a fixed set of random strokes, each sample a
//!   jittered, noised rendering. TMs trained on these learn clauses tens
//!   of literals long, as on MNIST.
//! * [`ImageStyle::Fashion`] — filled-blob images (≈35% ink, like
//!   F-MNIST's clothing silhouettes), denser literals, longer clauses.
//! * [`bow`] — two-class Zipf bag-of-words with class-conditional token
//!   lifts, ~2.5% document density at 5k features (IMDb binarized
//!   BoW territory), the regime where the paper sees its 13–15×
//!   inference speedups.

use crate::data::binarize;
use crate::data::dataset::Dataset;
use crate::util::Rng;

/// Image generator style.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ImageStyle {
    /// Thin-stroke, MNIST-like ink density.
    Digits,
    /// Filled-patch, Fashion-MNIST-like ink density.
    Fashion,
}

const SIDE: usize = 28;
const PIXELS: usize = SIDE * SIDE;

/// Class template: strokes (Digits) or filled rectangles (Fashion),
/// rendered to a greyscale prototype.
fn class_prototype(style: ImageStyle, class: usize, rng: &mut Rng) -> Vec<u8> {
    let mut img = vec![0u8; PIXELS];
    match style {
        ImageStyle::Digits => {
            // 3-5 strokes: random walks with momentum, 1px wide
            let strokes = 3 + (class % 3);
            for _ in 0..strokes {
                let mut x = 4 + rng.below(20) as i32;
                let mut y = 4 + rng.below(20) as i32;
                let mut dx = rng.below(3) as i32 - 1;
                let mut dy = rng.below(3) as i32 - 1;
                if dx == 0 && dy == 0 {
                    dy = 1;
                }
                for _ in 0..14 {
                    for (ox, oy) in [(0, 0), (1, 0), (0, 1)] {
                        let (px, py) = (x + ox, y + oy);
                        if (0..SIDE as i32).contains(&px) && (0..SIDE as i32).contains(&py) {
                            img[py as usize * SIDE + px as usize] = 220;
                        }
                    }
                    if rng.bern(0.25) {
                        dx = (dx + rng.below(3) as i32 - 1).clamp(-1, 1);
                        dy = (dy + rng.below(3) as i32 - 1).clamp(-1, 1);
                    }
                    x = (x + dx).clamp(1, SIDE as i32 - 2);
                    y = (y + dy).clamp(1, SIDE as i32 - 2);
                }
            }
        }
        ImageStyle::Fashion => {
            // 2-3 filled rectangles: a chunky silhouette
            let rects = 2 + (class % 2);
            for _ in 0..rects {
                let x0 = rng.below(14) as usize + 2;
                let y0 = rng.below(14) as usize + 2;
                let w = 6 + rng.below(10) as usize;
                let h = 6 + rng.below(10) as usize;
                for y in y0..(y0 + h).min(SIDE - 1) {
                    for x in x0..(x0 + w).min(SIDE - 1) {
                        img[y * SIDE + x] = img[y * SIDE + x].saturating_add(150);
                    }
                }
            }
        }
    }
    img
}

/// Render one sample: prototype + translation jitter + pixel noise.
fn render_sample(proto: &[u8], rng: &mut Rng) -> Vec<u8> {
    let dx = rng.below(5) as i32 - 2;
    let dy = rng.below(5) as i32 - 2;
    let mut img = vec![0u8; PIXELS];
    for y in 0..SIDE {
        for x in 0..SIDE {
            let sx = x as i32 - dx;
            let sy = y as i32 - dy;
            if (0..SIDE as i32).contains(&sx) && (0..SIDE as i32).contains(&sy) {
                img[y * SIDE + x] = proto[sy as usize * SIDE + sx as usize];
            }
        }
    }
    for p in img.iter_mut() {
        if rng.bern(0.02) {
            *p = if *p > 128 { 0 } else { 200 }; // salt & pepper
        } else if *p > 0 {
            // grey jitter so multi-level thresholds carry signal
            let jitter = rng.below(80) as i32 - 40;
            *p = (*p as i32 + jitter).clamp(0, 255) as u8;
        }
    }
    img
}

/// Generate `samples` greyscale images across `classes` classes.
pub fn images(
    style: ImageStyle,
    classes: usize,
    samples: usize,
    seed: u64,
) -> (Vec<Vec<u8>>, Vec<usize>) {
    let mut rng = Rng::new(seed ^ 0x1111_2222_3333_4444);
    let protos: Vec<Vec<u8>> = (0..classes)
        .map(|c| class_prototype(style, c, &mut rng))
        .collect();
    let mut imgs = Vec::with_capacity(samples);
    let mut labels = Vec::with_capacity(samples);
    for _ in 0..samples {
        let y = rng.below(classes as u32) as usize;
        imgs.push(render_sample(&protos[y], &mut rng));
        labels.push(y);
    }
    (imgs, labels)
}

/// Synthetic image dataset, binarized with `levels` thresholds —
/// features = `levels * 784`, exactly the paper's M1–M4 / F1–F4 grid.
pub fn image_dataset(
    style: ImageStyle,
    classes: usize,
    samples: usize,
    levels: usize,
    seed: u64,
) -> Dataset {
    let (imgs, labels) = images(style, classes, samples, seed);
    let rows = binarize::binarize_images(&imgs, levels);
    let name = match style {
        ImageStyle::Digits => format!("synth-mnist-M{levels}"),
        ImageStyle::Fashion => format!("synth-fashion-F{levels}"),
    };
    Dataset::from_rows(name, levels * PIXELS, classes, &rows, labels)
}

/// Noisy XOR — the canonical TM benchmark (Granmo 2018), and the
/// parallel-training reference workload: `y = x0 XOR x1` with
/// `features - 2` random distractors and labels flipped with
/// probability `noise`. Non-linearly separable, so a TM must learn the
/// four minterm clauses through the label noise; `noise = 0.0` gives a
/// clean test split.
pub fn noisy_xor(features: usize, samples: usize, noise: f64, seed: u64) -> Dataset {
    assert!(features >= 2, "noisy XOR needs at least x0, x1");
    let mut rng = Rng::new(seed ^ 0xab0b_ab0b_ab0b_ab0b);
    let mut rows = Vec::with_capacity(samples);
    let mut labels = Vec::with_capacity(samples);
    for _ in 0..samples {
        let bits: Vec<bool> = (0..features).map(|_| rng.bern(0.5)).collect();
        let mut y = (bits[0] ^ bits[1]) as usize;
        if noise > 0.0 && rng.bern(noise) {
            y = 1 - y;
        }
        rows.push(bits);
        labels.push(y);
    }
    Dataset::from_rows("synth-noisy-xor", features, 2, &rows, labels)
}

/// Two-class Zipf bag-of-words (IMDb stand-in).
///
/// `features` is the vocabulary size (paper: 5k/10k/15k/20k). Each
/// document draws ~`doc_tokens` tokens from a Zipf(1.1) rank
/// distribution; 10% of the vocabulary is class-polarized (its
/// probability is boosted for one class and suppressed for the other),
/// giving a learnable signal with realistic (~2-5%) feature density.
pub fn bow(features: usize, samples: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0x5555_6666_7777_8888);
    // Zipf CDF over ranks (power 1.1)
    let weights: Vec<f64> = (0..features).map(|r| 1.0 / (r as f64 + 1.0).powf(1.1)).collect();
    let total: f64 = weights.iter().sum();
    let mut cdf = Vec::with_capacity(features);
    let mut acc = 0.0;
    for w in &weights {
        acc += w / total;
        cdf.push(acc);
    }
    // polarized tokens: every 10th rank alternates class affinity
    let polarity_of = |rank: usize| -> Option<usize> {
        if rank % 10 == 3 {
            Some((rank / 10) % 2)
        } else {
            None
        }
    };
    let doc_tokens = (features / 40).clamp(120, 600); // density ≈ 2.5%

    let mut rows = Vec::with_capacity(samples);
    let mut labels = Vec::with_capacity(samples);
    for _ in 0..samples {
        let y = rng.bern(0.5) as usize;
        let mut row = vec![false; features];
        let mut placed = 0;
        while placed < doc_tokens {
            let u = rng.unit_f64();
            let rank = cdf.partition_point(|&c| c < u).min(features - 1);
            // class-conditional acceptance for polarized tokens
            let keep = match polarity_of(rank) {
                Some(cls) if cls == y => true,
                Some(_) => rng.bern(0.15),
                None => true,
            };
            if keep {
                if !row[rank] {
                    placed += 1;
                }
                row[rank] = true;
            }
        }
        rows.push(row);
        labels.push(y);
    }
    Dataset::from_rows(format!("synth-imdb-{features}"), features, 2, &rows, labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn images_are_deterministic_per_seed() {
        let (a, la) = images(ImageStyle::Digits, 4, 10, 7);
        let (b, lb) = images(ImageStyle::Digits, 4, 10, 7);
        assert_eq!(a, b);
        assert_eq!(la, lb);
        let (c, _) = images(ImageStyle::Digits, 4, 10, 8);
        assert_ne!(a, c);
    }

    #[test]
    fn digit_ink_density_is_mnist_like() {
        let (imgs, _) = images(ImageStyle::Digits, 10, 100, 1);
        let ink: usize = imgs
            .iter()
            .map(|im| im.iter().filter(|&&p| p >= 128).count())
            .sum();
        let frac = ink as f64 / (imgs.len() * PIXELS) as f64;
        // MNIST is ~19% ink >= 128; accept a generous band
        assert!((0.05..0.35).contains(&frac), "ink fraction {frac}");
    }

    #[test]
    fn fashion_is_denser_than_digits() {
        let ink = |style| {
            let (imgs, _) = images(style, 10, 100, 2);
            imgs.iter()
                .map(|im| im.iter().filter(|&&p| p >= 128).count())
                .sum::<usize>() as f64
                / (100 * PIXELS) as f64
        };
        assert!(ink(ImageStyle::Fashion) > ink(ImageStyle::Digits));
    }

    #[test]
    fn image_dataset_shapes_match_paper_grid() {
        for levels in 1..=4 {
            let d = image_dataset(ImageStyle::Digits, 10, 20, levels, 3);
            assert_eq!(d.features, levels * 784);
            assert_eq!(d.len(), 20);
            assert_eq!(d.classes, 10);
        }
    }

    #[test]
    fn noisy_xor_shapes_and_noise() {
        let clean = noisy_xor(12, 500, 0.0, 7);
        assert_eq!(clean.features, 12);
        assert_eq!(clean.classes, 2);
        assert_eq!(clean.len(), 500);
        // clean labels are exactly the XOR of the first two features
        for i in 0..clean.len() {
            let l = clean.literals(i);
            assert_eq!(clean.label(i), (l.get(0) ^ l.get(1)) as usize);
        }
        // noisy labels disagree at roughly the noise rate
        let noisy = noisy_xor(12, 4000, 0.2, 7);
        let flipped = (0..noisy.len())
            .filter(|&i| {
                let l = noisy.literals(i);
                noisy.label(i) != (l.get(0) ^ l.get(1)) as usize
            })
            .count();
        let rate = flipped as f64 / noisy.len() as f64;
        assert!((rate - 0.2).abs() < 0.03, "flip rate {rate}");
        // deterministic per seed
        let again = noisy_xor(12, 4000, 0.2, 7);
        assert_eq!(
            (0..noisy.len()).map(|i| noisy.label(i)).collect::<Vec<_>>(),
            (0..again.len()).map(|i| again.label(i)).collect::<Vec<_>>()
        );
    }

    #[test]
    fn bow_density_is_imdb_like() {
        let d = bow(5000, 50, 4);
        let density = d.mean_feature_density();
        assert!((0.01..0.06).contains(&density), "density {density}");
        assert_eq!(d.classes, 2);
        assert_eq!(d.features, 5000);
    }

    #[test]
    fn bow_is_learnable() {
        use crate::eval::Backend;
        use crate::tm::{params::TMParams, trainer::Trainer};
        let train = bow(500, 300, 5);
        let test = bow(500, 150, 6);
        let params = TMParams::new(2, 40, 500).with_threshold(15).with_s(5.0);
        let mut tr = Trainer::new(params, Backend::Indexed);
        for _ in 0..5 {
            tr.train_epoch(train.iter());
        }
        let acc = tr.accuracy(test.iter());
        assert!(acc > 0.75, "accuracy {acc}");
    }

    #[test]
    fn images_are_learnable() {
        use crate::eval::Backend;
        use crate::tm::{params::TMParams, trainer::Trainer};
        let all = image_dataset(ImageStyle::Digits, 4, 600, 1, 10);
        let train = all.slice(0, 400);
        let test = all.slice(400, 600);
        let params = TMParams::new(4, 60, 784).with_threshold(20).with_s(5.0);
        let mut tr = Trainer::new(params, Backend::Indexed);
        for _ in 0..4 {
            tr.train_epoch(train.iter());
        }
        let acc = tr.accuracy(test.iter());
        assert!(acc > 0.7, "accuracy {acc}");
    }
}
