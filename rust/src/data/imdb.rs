//! IMDb-style bag-of-words loading with synthetic fallback.
//!
//! Real-data path: a plain-text "libsvm-lite" format, one document per
//! line — `label idx idx idx ...` with `label ∈ {0,1}` and `idx` the
//! set feature ids. (The paper binarizes IMDb into a k-hot BoW over the
//! 5k–20k most frequent terms; exporting that to this format is a
//! one-liner from any tokenizer.) Parsing goes straight into the sparse
//! k-hot representation ([`SparseDataset`]) — the input is ≥95% zeros,
//! so the sparse-delta inference engine consumes it without ever
//! densifying; [`parse_sparse_bow`] densifies only for callers that
//! need `[x, ¬x]` literal vectors. Repeated feature indices on a line
//! are rejected (a double-set index is a corrupt export, not a k-hot
//! document). Fallback: the calibrated Zipf generator in
//! [`crate::data::synth`].

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::data::dataset::Dataset;
use crate::data::sparse::{SparseDataset, SparseSample};
use crate::data::synth;

/// Parse the one-line-per-document sparse format into the k-hot
/// representation (no densification).
pub fn parse_sparse_bow_to_sparse(text: &str, features: usize) -> Result<SparseDataset> {
    let mut samples: Vec<SparseSample> = Vec::new();
    let mut labels = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_ascii_whitespace();
        let label: usize = parts
            .next()
            .unwrap()
            .parse()
            .with_context(|| format!("line {}: bad label", lineno + 1))?;
        ensure!(label < 2, "line {}: label must be 0/1", lineno + 1);
        let mut set: Vec<u32> = Vec::new();
        for tok in parts {
            let idx: usize = tok
                .parse()
                .with_context(|| format!("line {}: bad index '{tok}'", lineno + 1))?;
            ensure!(
                idx < features,
                "line {}: index {idx} >= features {features}",
                lineno + 1
            );
            set.push(idx as u32);
        }
        let nnz = set.len();
        let sample = SparseSample::new(features, set);
        ensure!(
            sample.nnz() == nnz,
            "line {}: repeated feature index (k-hot documents set each index once)",
            lineno + 1
        );
        samples.push(sample);
        labels.push(label);
    }
    ensure!(!samples.is_empty(), "no documents in file");
    Ok(SparseDataset::new(
        format!("imdb-bow-{features}"),
        features,
        2,
        samples,
        labels,
    ))
}

/// Parse the one-line-per-document sparse format, densified into
/// `[x, ¬x]` literal vectors.
pub fn parse_sparse_bow(text: &str, features: usize) -> Result<Dataset> {
    Ok(parse_sparse_bow_to_sparse(text, features)?.to_dense())
}

/// Read and parse a provided BoW file, reporting *why* a fallback
/// happens — a broken file must never be silently replaced by
/// synthetic data (scores on fabricated documents would masquerade as
/// real results).
fn try_load_sparse(path: &Path, features: usize) -> Option<SparseDataset> {
    let text = match std::fs::read_to_string(path) {
        Ok(text) => text,
        Err(e) => {
            eprintln!(
                "warning: cannot read bow file {}: {e}; falling back to synthetic data",
                path.display()
            );
            return None;
        }
    };
    match parse_sparse_bow_to_sparse(&text, features) {
        Ok(ds) => Some(ds),
        Err(e) => {
            eprintln!(
                "warning: cannot parse bow file {}: {e:#}; falling back to synthetic data",
                path.display()
            );
            None
        }
    }
}

/// Load a sparse-BoW file if present, else synthesize (with a stderr
/// warning when a *provided* file is unreadable or malformed).
/// `samples` caps the returned size either way; train/test use
/// disjoint synthetic streams (`split_tag` 0 = train, 1 = test).
pub fn load_or_synthesize(
    path: Option<&Path>,
    features: usize,
    samples: usize,
    split_tag: u64,
    seed: u64,
) -> Dataset {
    if let Some(path) = path {
        if let Some(ds) = try_load_sparse(path, features) {
            return ds.to_dense().take(samples);
        }
    }
    let skip = (split_tag as usize) * samples;
    synth::bow(features, samples + skip, seed).slice(skip, skip + samples)
}

/// Sparse twin of [`load_or_synthesize`]: the file path parses without
/// densifying; the synthetic fallback is sparsified after generation.
pub fn load_or_synthesize_sparse(
    path: Option<&Path>,
    features: usize,
    samples: usize,
    split_tag: u64,
    seed: u64,
) -> SparseDataset {
    if let Some(path) = path {
        if let Some(ds) = try_load_sparse(path, features) {
            return ds.take(samples);
        }
    }
    SparseDataset::from_dense(&load_or_synthesize(None, features, samples, split_tag, seed))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sparse_format() {
        let text = "0 1 3 5\n1 0 2\n# comment\n\n0 4\n";
        let ds = parse_sparse_bow(text, 6).unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.label(0), 0);
        assert_eq!(ds.label(1), 1);
        let l0 = ds.literals(0);
        assert!(!l0.get(0) && l0.get(1) && l0.get(3) && l0.get(5));
        assert!(l0.get(6)); // ¬x0
    }

    #[test]
    fn parses_straight_into_sparse() {
        let text = "1 5 1 3\n0 2\n";
        let sp = parse_sparse_bow_to_sparse(text, 6).unwrap();
        assert_eq!(sp.len(), 2);
        assert_eq!(sp.sample(0).ones(), &[1, 3, 5]); // sorted
        assert_eq!(sp.sample(1).ones(), &[2]);
        assert_eq!(sp.label(0), 1);
        // densified twin is literal-identical
        let dense = parse_sparse_bow(text, 6).unwrap();
        for i in 0..2 {
            assert_eq!(&sp.sample(i).to_literals(), dense.literals(i));
        }
    }

    #[test]
    fn rejects_bad_input() {
        assert!(parse_sparse_bow("2 1", 4).is_err()); // label out of range
        assert!(parse_sparse_bow("0 9", 4).is_err()); // index out of range
        assert!(parse_sparse_bow("x 1", 4).is_err()); // bad label
        assert!(parse_sparse_bow("", 4).is_err()); // empty
    }

    #[test]
    fn rejects_repeated_feature_index() {
        // regression: '0 2 2' used to silently double-set feature 2
        let err = parse_sparse_bow("0 1 2 2\n", 4).unwrap_err();
        assert!(
            err.to_string().contains("repeated feature index"),
            "unexpected error: {err}"
        );
        assert!(err.to_string().contains("line 1"), "{err}");
        // the same line deeper in the file reports its own line number
        let err = parse_sparse_bow("0 1\n1 3 3\n", 4).unwrap_err();
        assert!(err.to_string().contains("line 2"), "{err}");
        // and the sparse parser rejects identically
        assert!(parse_sparse_bow_to_sparse("0 2 2\n", 4).is_err());
    }

    #[test]
    fn fallback_synthesizes_with_disjoint_splits() {
        let train = load_or_synthesize(None, 1000, 30, 0, 11);
        let test = load_or_synthesize(None, 1000, 30, 1, 11);
        assert_eq!(train.len(), 30);
        assert_eq!(test.len(), 30);
        let same = (0..30)
            .filter(|&i| train.literals(i) == test.literals(i))
            .count();
        assert_eq!(same, 0);
    }

    #[test]
    fn sparse_loader_matches_dense_loader() {
        let dense = load_or_synthesize(None, 500, 20, 0, 13);
        let sp = load_or_synthesize_sparse(None, 500, 20, 0, 13);
        assert_eq!(sp.len(), dense.len());
        for i in 0..sp.len() {
            assert_eq!(&sp.sample(i).to_literals(), dense.literals(i));
            assert_eq!(sp.label(i), dense.label(i));
        }
    }

    #[test]
    fn malformed_file_falls_back_to_synthetic() {
        // a provided-but-broken file must still yield a dataset (the
        // loader warns on stderr) rather than erroring or panicking —
        // and the result is the synthetic stream, not a partial parse
        let p = std::env::temp_dir().join(format!("tmi-bow-bad-{}.txt", std::process::id()));
        std::fs::write(&p, "0 1 1\n").unwrap(); // repeated index: rejected
        let ds = load_or_synthesize(Some(&p), 500, 10, 0, 11);
        let synth = load_or_synthesize(None, 500, 10, 0, 11);
        assert_eq!(ds.len(), synth.len());
        assert_eq!(ds.literals(0), synth.literals(0));
        let sp = load_or_synthesize_sparse(Some(&p), 500, 10, 0, 11);
        assert_eq!(sp.len(), 10);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn file_path_roundtrip() {
        let p = std::env::temp_dir().join(format!("tmi-bow-{}.txt", std::process::id()));
        std::fs::write(&p, "1 0 1\n0 2\n").unwrap();
        let ds = load_or_synthesize(Some(&p), 3, 10, 0, 0);
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.label(0), 1);
        let sp = load_or_synthesize_sparse(Some(&p), 3, 10, 0, 0);
        assert_eq!(sp.sample(0).ones(), &[0, 1]);
        std::fs::remove_file(&p).unwrap();
    }
}
