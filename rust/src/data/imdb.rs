//! IMDb-style bag-of-words loading with synthetic fallback.
//!
//! Real-data path: a plain-text "libsvm-lite" format, one document per
//! line — `label idx idx idx ...` with `label ∈ {0,1}` and `idx` the
//! set feature ids. (The paper binarizes IMDb into a k-hot BoW over the
//! 5k–20k most frequent terms; exporting that to this format is a
//! one-liner from any tokenizer.) Fallback: the calibrated Zipf
//! generator in [`crate::data::synth`].

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::data::dataset::Dataset;
use crate::data::synth;

/// Parse the one-line-per-document sparse format.
pub fn parse_sparse_bow(text: &str, features: usize) -> Result<Dataset> {
    let mut rows: Vec<Vec<bool>> = Vec::new();
    let mut labels = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_ascii_whitespace();
        let label: usize = parts
            .next()
            .unwrap()
            .parse()
            .with_context(|| format!("line {}: bad label", lineno + 1))?;
        ensure!(label < 2, "line {}: label must be 0/1", lineno + 1);
        let mut row = vec![false; features];
        for tok in parts {
            let idx: usize = tok
                .parse()
                .with_context(|| format!("line {}: bad index '{tok}'", lineno + 1))?;
            ensure!(
                idx < features,
                "line {}: index {idx} >= features {features}",
                lineno + 1
            );
            row[idx] = true;
        }
        rows.push(row);
        labels.push(label);
    }
    ensure!(!rows.is_empty(), "no documents in file");
    Ok(Dataset::from_rows(
        format!("imdb-bow-{features}"),
        features,
        2,
        &rows,
        labels,
    ))
}

/// Load a sparse-BoW file if present, else synthesize. `samples` caps
/// the returned size either way; train/test use disjoint synthetic
/// streams (`split_tag` 0 = train, 1 = test).
pub fn load_or_synthesize(
    path: Option<&Path>,
    features: usize,
    samples: usize,
    split_tag: u64,
    seed: u64,
) -> Dataset {
    if let Some(path) = path {
        if let Ok(text) = std::fs::read_to_string(path) {
            if let Ok(ds) = parse_sparse_bow(&text, features) {
                return ds.take(samples);
            }
        }
    }
    let skip = (split_tag as usize) * samples;
    synth::bow(features, samples + skip, seed).slice(skip, skip + samples)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sparse_format() {
        let text = "0 1 3 5\n1 0 2\n# comment\n\n0 4\n";
        let ds = parse_sparse_bow(text, 6).unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.label(0), 0);
        assert_eq!(ds.label(1), 1);
        let l0 = ds.literals(0);
        assert!(!l0.get(0) && l0.get(1) && l0.get(3) && l0.get(5));
        assert!(l0.get(6)); // ¬x0
    }

    #[test]
    fn rejects_bad_input() {
        assert!(parse_sparse_bow("2 1", 4).is_err()); // label out of range
        assert!(parse_sparse_bow("0 9", 4).is_err()); // index out of range
        assert!(parse_sparse_bow("x 1", 4).is_err()); // bad label
        assert!(parse_sparse_bow("", 4).is_err()); // empty
    }

    #[test]
    fn fallback_synthesizes_with_disjoint_splits() {
        let train = load_or_synthesize(None, 1000, 30, 0, 11);
        let test = load_or_synthesize(None, 1000, 30, 1, 11);
        assert_eq!(train.len(), 30);
        assert_eq!(test.len(), 30);
        let same = (0..30)
            .filter(|&i| train.literals(i) == test.literals(i))
            .count();
        assert_eq!(same, 0);
    }

    #[test]
    fn file_path_roundtrip() {
        let p = std::env::temp_dir().join(format!("tmi-bow-{}.txt", std::process::id()));
        std::fs::write(&p, "1 0 1\n0 2\n").unwrap();
        let ds = load_or_synthesize(Some(&p), 3, 10, 0, 0);
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.label(0), 1);
        std::fs::remove_file(&p).unwrap();
    }
}
