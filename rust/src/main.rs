//! `tmi` — clause-indexed Tsetlin Machine CLI (Layer-3 entry point).
//!
//! ```text
//! tmi train       train a model on a (real or synthetic) dataset
//! tmi eval        evaluate a saved model
//! tmi table       regenerate paper Table 1/2/3 (+ the figure CSVs)
//! tmi work-ratio  §3 Remarks: measured work-ratio statistics
//! tmi serve       serving coordinator (CPU and/or XLA backends) over TCP:
//!                 hot-swap snapshot routes, bounded queues, load shedding;
//!                 --registry serves (and crash-recovers) a durable registry
//! tmi loadgen     open/closed-loop TCP load generator -> BENCH_serve.json
//! tmi promcheck   validate a Prometheus text exposition (file or stdin)
//! tmi registry    inspect/maintain a model registry: ls | verify | gc
//! tmi info        PJRT platform + artifact manifest
//! ```
//!
//! Argument parsing is hand-rolled (`--key value` / `--flag`): the
//! offline build has no clap (DESIGN.md §Substitutions).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, PoisonError};

use anyhow::{bail, ensure, Context, Result};

use tsetlin_index::bench_harness::figures::write_figures;
use tsetlin_index::bench_harness::tables::{run_table, Scale, TableId};
use tsetlin_index::cluster::{
    serve_control, serve_node, serve_router, ControlConfig, ControlPlane, NodeOptions, NodeSpec,
    NodeState, Router, RouterConfig,
};
use tsetlin_index::coordinator::online::{replay_feedback, reseed_seed};
use tsetlin_index::coordinator::server::{serve_metrics_http_with, serve_tcp_with};
use tsetlin_index::coordinator::{
    BatchPolicy, Coordinator, CpuBackend, LoadgenConfig, OnlineConfig, OnlineLearner, PublishFn,
    PublishReport, RouteConfig, ServeOptions, XlaBackend,
};
use tsetlin_index::data::mnist::Split;
use tsetlin_index::data::synth::ImageStyle;
use tsetlin_index::data::{imdb, mnist, Dataset};
use tsetlin_index::engine::{argmax, InferMode, ModelSnapshot, SPARSE_DENSITY_THRESHOLD};
use tsetlin_index::eval::Backend;
use tsetlin_index::obs::{self, journal, EventKind};
use tsetlin_index::parallel::{resolve_threads, ParallelTrainer, DEFAULT_STALE_WINDOW};
use tsetlin_index::registry::store::DEFAULT_RETAIN;
use tsetlin_index::registry::{
    read_generation, sync_published, FeedbackWal, Registry, SyncEvent, WatchState,
};
use tsetlin_index::runtime::{Manifest, Runtime};
use tsetlin_index::tm::bank::TaLayout;
use tsetlin_index::tm::classifier::MultiClassTM;
use tsetlin_index::tm::io::{self, DenseModel};
use tsetlin_index::tm::params::TMParams;
use tsetlin_index::tm::trainer::{EpochStats, Trainer};
use tsetlin_index::util::{BitVec, Rng, SimdMode};

/// `--key value` / `--flag` argument bag.
struct Args {
    values: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut values = HashMap::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    values.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.push(key.to_string());
                    i += 1;
                }
            } else {
                bail!("unexpected positional argument '{a}'");
            }
        }
        Ok(Args { values, flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(String::as_str)
    }

    fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("bad value for --{key}: '{v}'")),
        }
    }

    fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

fn load_dataset(args: &Args, split: Split) -> Result<Dataset> {
    let name = args.get_or("dataset", "mnist");
    let data_dir = args.get("data-dir").map(PathBuf::from);
    let samples = args.parse_or(
        "samples",
        if split == Split::Train { 1000 } else { 500 },
    )?;
    let seed: u64 = args.parse_or("seed", 42)?;
    match name.as_str() {
        "mnist" | "fashion" => {
            let levels = args.parse_or("levels", 1)?;
            let style = if name == "mnist" {
                ImageStyle::Digits
            } else {
                ImageStyle::Fashion
            };
            Ok(mnist::load_or_synthesize(
                data_dir.as_deref(),
                style,
                split,
                levels,
                samples,
                seed,
            ))
        }
        "imdb" => {
            let features = args.parse_or("features", 5000)?;
            let tag = if split == Split::Train { 0 } else { 1 };
            Ok(imdb::load_or_synthesize(
                args.get("bow-file").map(Path::new),
                features,
                samples,
                tag,
                seed,
            ))
        }
        other => bail!("unknown dataset '{other}' (mnist|fashion|imdb)"),
    }
}

/// Parse `--infer auto|dense|sparse` (dense/sparse engine selection for
/// indexed-backend inference).
fn parse_infer_mode(args: &Args) -> Result<InferMode> {
    args.get_or("infer", "auto").parse().map_err(anyhow::Error::msg)
}

/// Parse `--simd auto|wide|scalar` (lane width for the bit-plane hot
/// loops, see `docs/TUNING.md`). Returns `None` when the flag is
/// absent so model-loading commands can keep the mode stored in the
/// model file instead of overriding it.
fn parse_simd_mode(args: &Args) -> Result<Option<SimdMode>> {
    match args.get("simd") {
        None => Ok(None),
        Some(v) => v.parse().map(Some).map_err(anyhow::Error::msg),
    }
}

/// One line explaining which inference engine serves this dataset —
/// the density auto-selection is otherwise invisible.
fn report_infer_choice(mode: InferMode, resolved: InferMode, density: f64) {
    match mode {
        InferMode::Auto => eprintln!(
            "auto-selected {} inference (feature density {:.4}, sparse below {})",
            resolved.name(),
            density,
            SPARSE_DENSITY_THRESHOLD
        ),
        forced => eprintln!("inference engine: {} (forced)", forced.name()),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let train = load_dataset(args, Split::Train)?;
    let test = load_dataset(args, Split::Test)?;
    let clauses: usize = args.parse_or("clauses", 1000)?;
    let epochs: usize = args.parse_or("epochs", 5)?;
    let backend: Backend = args
        .get_or("backend", "indexed")
        .parse()
        .map_err(anyhow::Error::msg)?;
    // --ta-layout sliced (default) = bit-sliced TA banks, word-parallel
    // feedback; scalar = the portable per-byte escape hatch. Both train
    // bit-identically — this only picks the state representation.
    let ta_layout: TaLayout = args
        .get_or("ta-layout", "sliced")
        .parse()
        .map_err(anyhow::Error::msg)?;
    // --simd auto (default) picks wide lanes where the budget fits;
    // wide/scalar force them. A dispatch choice, not a hyper-parameter:
    // training is bit-identical across all three settings.
    let simd = parse_simd_mode(args)?.unwrap_or_default();
    let params = TMParams::from_total_clauses(train.classes, clauses, train.features)
        .with_threshold(args.parse_or("threshold", 25)?)
        .with_s(args.parse_or("s", 6.0)?)
        .with_seed(args.parse_or("seed", 42)?)
        .with_weighted(args.has_flag("weighted"))
        .with_ta_layout(ta_layout)
        .with_simd(simd);
    // --threads 0 = every available core; 1 (default) = the sequential
    // trainer; >= 2 = the clause-sharded parallel trainer.
    let threads = resolve_threads(args.parse_or("threads", 1)?);
    let stale_window: usize = args.parse_or("stale-window", DEFAULT_STALE_WINDOW)?;
    if threads > 1 && backend != Backend::Indexed {
        bail!(
            "--threads {} requires the indexed backend: clause shards keep \
             per-shard falsification indexes (got --backend {})",
            threads,
            backend.name()
        );
    }
    eprintln!(
        "training {} epochs on {} ({} samples, {} features, {} classes, {} clauses/class, backend={}, threads={}, ta-layout={}, simd={})",
        epochs,
        train.name,
        train.len(),
        train.features,
        train.classes,
        params.clauses_per_class,
        backend.name(),
        threads,
        params.ta_layout.name(),
        params.simd.name()
    );
    let infer_mode = parse_infer_mode(args)?;
    let mut order_rng = Rng::new(args.parse_or("seed", 42u64)? ^ 0x0def_ace0);
    let mut trainer = if threads > 1 {
        AnyTrainer::Par(ParallelTrainer::new(params, threads).with_stale_window(stale_window))
    } else {
        AnyTrainer::Seq(Trainer::new(params, backend))
    };
    trainer.set_infer_mode(infer_mode);
    // selection only applies to the indexed backend's engines (the
    // parallel trainer is always indexed); the per-epoch test accuracy
    // below is served by whichever engine this resolves to
    if backend == Backend::Indexed {
        let resolved = trainer.resolve_infer_mode(test.all_literals());
        report_infer_choice(infer_mode, resolved, test.mean_feature_density());
    }
    for epoch in 0..epochs {
        let order = train.epoch_order(&mut order_rng);
        let stats = trainer.train_epoch(train.iter_order(&order));
        let t0 = std::time::Instant::now();
        let acc = trainer.accuracy(test.iter());
        let test_s = t0.elapsed().as_secs_f64();
        println!(
            "epoch {:>3}  train {:.2}s  test {:.2}s  accuracy {:.4}  mean-clause-len {:.1}  {:.0} updates/s",
            epoch + 1,
            stats.elapsed.as_secs_f64(),
            test_s,
            acc,
            trainer.tm().mean_clause_length(),
            stats.updates_per_sec
        );
    }
    if let Some(out) = args.get("out") {
        io::save(trainer.tm(), out)?;
        eprintln!("saved model to {out}");
    }
    if let Some(dir) = args.get("registry") {
        let route = args.get_or("route", "cpu");
        let retain: usize = args.parse_or("retain", DEFAULT_RETAIN)?;
        let mut registry = Registry::open(dir, retain)?;
        let version = registry.publish(&route, trainer.tm(), infer_mode)?;
        eprintln!("published route '{route}' v{version} to registry {dir}");
    }
    Ok(())
}

/// The `tmi train` trainer: sequential (any backend) or clause-sharded
/// parallel (indexed). One variant is always live — no unreachable
/// states to re-prove at each use site.
enum AnyTrainer {
    Seq(Trainer),
    Par(ParallelTrainer),
}

impl AnyTrainer {
    fn train_epoch<'a>(
        &mut self,
        samples: impl Iterator<Item = (&'a BitVec, usize)>,
    ) -> EpochStats {
        match self {
            AnyTrainer::Seq(t) => t.train_epoch(samples),
            AnyTrainer::Par(p) => p.train_epoch(samples),
        }
    }

    fn accuracy<'a>(&mut self, samples: impl Iterator<Item = (&'a BitVec, usize)>) -> f64 {
        match self {
            AnyTrainer::Seq(t) => t.accuracy(samples),
            AnyTrainer::Par(p) => p.accuracy(samples),
        }
    }

    fn tm(&self) -> &MultiClassTM {
        match self {
            AnyTrainer::Seq(t) => &t.tm,
            AnyTrainer::Par(p) => p.tm(),
        }
    }

    fn set_infer_mode(&mut self, mode: InferMode) {
        match self {
            AnyTrainer::Seq(t) => t.set_infer_mode(mode),
            AnyTrainer::Par(p) => p.set_infer_mode(mode),
        }
    }

    fn resolve_infer_mode(&mut self, batch: &[BitVec]) -> InferMode {
        match self {
            AnyTrainer::Seq(t) => t.resolve_infer_mode(batch),
            AnyTrainer::Par(p) => p.trainer().resolve_infer_mode(batch),
        }
    }
}

fn cmd_eval(args: &Args) -> Result<()> {
    let model_path = args.get("model").context("--model required")?;
    let mut tm = io::load(model_path)?;
    // explicit --simd overrides the mode stored in the model file
    if let Some(simd) = parse_simd_mode(args)? {
        tm.set_simd(simd);
    }
    let test = load_dataset(args, Split::Test)?;
    let backend: Backend = args
        .get_or("backend", "indexed")
        .parse()
        .map_err(anyhow::Error::msg)?;
    let threads: usize = args.parse_or("threads", 1)?;
    let infer_mode = parse_infer_mode(args)?;
    let mut trainer = Trainer::from_machine(tm, backend)
        .with_infer_threads(threads)
        .with_infer_mode(infer_mode);
    // Batch scoring over the whole set: for the indexed backend this is
    // the class-fused engine (or, for low-density k-hot inputs, the
    // O(nnz) sparse-delta engine), sharded across --threads workers.
    // Score width comes from the model — a dataset with more labels
    // than the model has classes still evaluates (those labels just
    // never match).
    if backend == Backend::Indexed {
        let resolved = trainer.resolve_infer_mode(test.all_literals());
        report_infer_choice(infer_mode, resolved, test.mean_feature_density());
    }
    let m = trainer.tm.classes();
    let mut flat = vec![0i32; test.len() * m];
    let t0 = std::time::Instant::now();
    trainer.score_batch_into(test.all_literals(), &mut flat);
    let correct = flat
        .chunks(m)
        .enumerate()
        .filter(|(i, row)| argmax(row) == test.label(*i))
        .count();
    let secs = t0.elapsed().as_secs_f64();
    let acc = if test.is_empty() {
        0.0
    } else {
        correct as f64 / test.len() as f64
    };
    println!(
        "accuracy {:.4} on {} ({} samples) in {:.3}s [{}{}]",
        acc,
        test.name,
        test.len(),
        secs,
        backend.name(),
        if threads > 1 {
            format!(" x{threads}")
        } else {
            String::new()
        }
    );
    Ok(())
}

fn cmd_table(args: &Args) -> Result<()> {
    let id = match args.get_or("id", "1").as_str() {
        "1" => TableId::Mnist,
        "2" => TableId::Imdb,
        "3" => TableId::Fashion,
        other => bail!("bad --id '{other}' (1|2|3)"),
    };
    let scale = match args.get_or("scale", "env").as_str() {
        "quick" => Scale::quick(),
        "standard" => Scale::standard(),
        "paper" => Scale::paper(),
        _ => Scale::from_env(),
    };
    let data_dir = args.get("data-dir").map(PathBuf::from);
    let table = run_table(id, &scale, data_dir.as_deref(), |cell| {
        eprintln!("  running {cell}");
    });
    println!("{}", table.render_markdown());
    if let Some(out_dir) = args.get("out-dir") {
        let out_dir = Path::new(out_dir);
        let (headers, rows) = table.csv_rows();
        let csv = out_dir.join(format!("table{}.csv", args.get_or("id", "1")));
        tsetlin_index::bench_harness::report::write_csv(&csv, &headers, &rows)?;
        let figs = write_figures(&table, out_dir)?;
        eprintln!("wrote {} and figures: {}", csv.display(), figs.join(", "));
    }
    Ok(())
}

fn cmd_work_ratio(args: &Args) -> Result<()> {
    let train = load_dataset(args, Split::Train)?;
    let clauses: usize = args.parse_or("clauses", 1000)?;
    let epochs: usize = args.parse_or("epochs", 3)?;
    let params = TMParams::from_total_clauses(train.classes, clauses, train.features)
        .with_threshold(args.parse_or("threshold", 25)?)
        .with_s(args.parse_or("s", 6.0)?);
    let mut trainer = Trainer::new(params, Backend::Indexed);
    let mut order_rng = Rng::new(0x0def_ace0);
    for _ in 0..epochs {
        let order = train.epoch_order(&mut order_rng);
        trainer.train_epoch(train.iter_order(&order));
    }
    let stats = trainer.index_stats().unwrap();
    println!(
        "{:>6} {:>10} {:>12} {:>14} {:>12} {:>12}",
        "class", "clauses", "mean-len", "mean-list-len", "work-ratio", "max-list"
    );
    for (i, st) in stats.iter().enumerate() {
        println!(
            "{:>6} {:>10} {:>12.1} {:>14.1} {:>12.4} {:>12}",
            i,
            st.clauses,
            st.mean_clause_length,
            st.mean_list_length,
            st.work_ratio,
            st.max_list_length
        );
    }
    let mean_ratio = stats.iter().map(|s| s.work_ratio).sum::<f64>() / stats.len() as f64;
    println!(
        "\noverall: mean clause length {:.1}, mean work ratio {:.4} (paper §3: ~0.02 MNIST, ~0.006 IMDb)",
        trainer.tm.mean_clause_length(),
        mean_ratio
    );
    Ok(())
}

/// Serve-socket tuning shared by `--model` and `--registry` serving.
/// The read/scrape timeouts used to be hard-coded in the server; they
/// are route-level policy and belong on the command line.
fn parse_serve_options(args: &Args) -> Result<ServeOptions> {
    let d = ServeOptions::default();
    Ok(ServeOptions {
        max_conns: args.parse_or("max-conns", d.max_conns)?,
        read_timeout: std::time::Duration::from_millis(
            args.parse_or("read-timeout-ms", d.read_timeout.as_millis() as u64)?,
        ),
        scrape_timeout: std::time::Duration::from_millis(
            args.parse_or("scrape-timeout-ms", d.scrape_timeout.as_millis() as u64)?,
        ),
    })
}

/// Online-learner cadence and sizing (`--feedback` serving):
/// `--publish-interval 0` disables the timer trigger,
/// `--publish-every 0` disables the count trigger.
fn parse_online_config(args: &Args) -> Result<OnlineConfig> {
    let d = OnlineConfig::default();
    let interval_ms: u64 = args.parse_or(
        "publish-interval",
        d.publish_interval.map(|i| i.as_millis() as u64).unwrap_or(0),
    )?;
    Ok(OnlineConfig {
        publish_every: args.parse_or("publish-every", d.publish_every)?,
        publish_interval: if interval_ms > 0 {
            Some(std::time::Duration::from_millis(interval_ms))
        } else {
            None
        },
        queue_cap: args.parse_or("feedback-queue-cap", d.queue_cap)?,
        window: args.parse_or("drift-window", d.window)?,
        wal_fsync: args.has_flag("wal-fsync"),
    })
}

fn cmd_serve(args: &Args) -> Result<()> {
    if args.get("node-id").is_some() {
        return cmd_serve_node(args);
    }
    if args.get("registry").is_some() {
        return cmd_serve_registry(args);
    }
    let model_path = args
        .get("model")
        .context("--model required (or --registry <dir>)")?
        .to_string();
    let mut tm = io::load(&model_path)?;
    // explicit --simd overrides the mode stored in the model file;
    // engines built from the machine pick it up via params (and the
    // --watch reloader re-applies it to every hot-swapped version)
    let simd_override = parse_simd_mode(args)?;
    if let Some(simd) = simd_override {
        tm.set_simd(simd);
    }
    let backend: Backend = args
        .get_or("backend", "indexed")
        .parse()
        .map_err(anyhow::Error::msg)?;
    let mut workers: usize = args.parse_or("workers", 1)?;
    let queue_cap: usize = args.parse_or("queue-cap", 1024)?;
    let infer_mode = parse_infer_mode(args)?;
    let mut coord = Coordinator::new();
    // The indexed backend serves a hot-swappable snapshot route: N
    // batcher workers over one bounded queue, scoring an immutable
    // versioned ModelSnapshot. Ablation backends (naive/bitpacked)
    // keep the single-worker factory route through CpuBackend so A/B
    // comparisons still measure the evaluator, not the route plumbing.
    let snapshot_route = backend == Backend::Indexed;
    if snapshot_route && args.get("workers").is_none() {
        // legacy contract: `--parallel N` used to parallelize the
        // indexed route; map it to workers rather than silently
        // serving single-threaded
        let parallel: usize = args.parse_or("parallel", 1)?;
        if parallel > 1 {
            eprintln!("serve: mapping legacy --parallel {parallel} to --workers {parallel}");
            workers = parallel;
        }
    }
    let feedback_on = args.has_flag("feedback");
    if feedback_on && !snapshot_route {
        bail!(
            "--feedback requires the indexed backend: the online learner \
             maintains the clause index through its O(1) update hooks"
        );
    }
    if feedback_on && args.has_flag("watch") {
        bail!(
            "--feedback and --watch are mutually exclusive with --model: the \
             online learner is the route's publisher (a file watcher would \
             overwrite its in-memory updates)"
        );
    }
    // With --feedback the route's learner owns a live Trainer around
    // the model; registration serves its first frozen snapshot so the
    // version stream has exactly one publisher (the trainer).
    let mut pending_trainer: Option<Trainer> = None;
    if snapshot_route {
        let snap = if feedback_on {
            let mut trainer =
                Trainer::from_machine(tm.clone(), Backend::Indexed).with_infer_mode(infer_mode);
            let snap = Arc::new(trainer.publish());
            pending_trainer = Some(trainer);
            snap
        } else {
            Arc::new(ModelSnapshot::with_mode(tm.clone(), 1, infer_mode))
        };
        coord.register_model(
            "cpu",
            snap,
            RouteConfig {
                policy: BatchPolicy::default(),
                workers,
                queue_cap,
                ..RouteConfig::default()
            },
        );
    } else {
        if args.has_flag("watch") {
            bail!("--watch requires the indexed backend (hot swap serves snapshots)");
        }
        coord.register_with_config(
            "cpu",
            {
                let tm = tm.clone();
                let parallel: usize = args.parse_or("parallel", 1)?;
                // clone per call: the factory re-runs to rebuild the
                // backend if the route's worker panics
                move || {
                    Ok(Box::new(CpuBackend::new_parallel(tm.clone(), backend, parallel)) as _)
                }
            },
            RouteConfig {
                policy: BatchPolicy::default(),
                workers: 1,
                queue_cap,
                ..RouteConfig::default()
            },
        )?;
    }
    if let Some(artifacts) = args.get("artifacts") {
        let artifacts = artifacts.to_string();
        let dense = DenseModel::from_tm(&tm);
        let batch: usize = args.parse_or("xla-batch", 32)?;
        let registered = coord.register_with_config(
            "xla",
            move || {
                let manifest = Manifest::load(&artifacts)?;
                let meta = manifest
                    .pick(batch, dense.features, dense.clauses_total, dense.classes)
                    .with_context(|| {
                        format!(
                            "no artifact variant for (features={}, clauses={}, classes={})",
                            dense.features, dense.clauses_total, dense.classes
                        )
                    })?
                    .clone();
                let rt = Runtime::cpu()?;
                let exe = rt.load_artifact(&manifest.hlo_path(&meta), meta)?;
                Ok(Box::new(XlaBackend::new(rt, exe, &dense)?) as _)
            },
            RouteConfig {
                policy: BatchPolicy {
                    max_batch: batch,
                    max_wait: std::time::Duration::from_millis(2),
                },
                workers: 1,
                queue_cap,
                ..RouteConfig::default()
            },
        );
        match registered {
            Ok(()) => eprintln!("registered XLA route 'xla'"),
            Err(e) => eprintln!("XLA route unavailable: {e:#}"),
        }
    }
    // Spawn the online learner (if any) before handing out serving
    // handles: a CoordinatorHandle captures the route's feedback sender
    // at handle() time. The publish hook's own handle only swaps, which
    // is shared state — creating it early is fine.
    let mut learner: Option<OnlineLearner> = None;
    if let Some(trainer) = pending_trainer.take() {
        let online_cfg = parse_online_config(args)?;
        let hook = coord.handle();
        let publish: PublishFn = Box::new(move |tr: &mut Trainer, _updates: u64| {
            let snap = Arc::new(tr.publish());
            let version = snap.version();
            hook.swap("cpu", snap).map_err(|e| e.to_string())?;
            let generation = hook.stats("cpu").and_then(|s| s.generation).unwrap_or(0);
            Ok(PublishReport {
                version,
                generation,
                durable: false,
            })
        });
        let metrics = coord.route_metrics("cpu").expect("route 'cpu' registered");
        let l = OnlineLearner::spawn("cpu", trainer, None, publish, metrics, online_cfg);
        coord
            .attach_learner("cpu", l.sender())
            .map_err(|e| anyhow::anyhow!("attaching learner to 'cpu': {e}"))?;
        eprintln!(
            "online learner on 'cpu': publish every {} update(s) / {} ms \
             (not durable — feedback survives crashes only with --registry)",
            online_cfg.publish_every,
            online_cfg
                .publish_interval
                .map(|i| i.as_millis().to_string())
                .unwrap_or_else(|| "off".into()),
        );
        learner = Some(l);
    }
    let listen = args.get_or("listen", "127.0.0.1:7070");
    let listener =
        std::net::TcpListener::bind(&listen).with_context(|| format!("binding {listen}"))?;
    eprintln!(
        "serving models {:?} on {listen} ({} worker(s)/route, queue bound {}; \
         protocol: 'infer <model> <feature-bits>' / 'stats <model>'{})",
        coord.models(),
        workers.max(1),
        queue_cap,
        if feedback_on {
            " / 'feedback <model> <label> <feature-bits>' / 'train <model> <label>:<bits> ...'"
        } else {
            ""
        },
    );
    let opts = parse_serve_options(args)?;
    let handle = coord.handle();
    let stop = shutdown_flag();
    setup_observability(args, &handle, &stop, opts)?;
    if args.has_flag("watch") {
        let interval =
            std::time::Duration::from_millis(args.parse_or("watch-interval-ms", 500u64)?);
        let watch_handle = handle.clone();
        let path = model_path.clone();
        let stop_watch = Arc::clone(&stop);
        std::thread::Builder::new()
            .name("tmi-watch".into())
            .spawn(move || {
                watch_model_file(&path, watch_handle, interval, infer_mode, simd_override, stop_watch)
            })
            .expect("spawning watch thread");
        eprintln!(
            "watching {model_path} (poll {}ms): republishing 'cpu' on content change",
            interval.as_millis()
        );
    }
    serve_tcp_with(listener, handle, Arc::clone(&stop), opts)?;
    eprintln!("shutdown: stopped accepting; draining queues");
    if let Some(l) = learner {
        // final-publish pending feedback while the route still serves
        l.shutdown();
    }
    coord.shutdown();
    dump_journal_on_shutdown("serve loop stopped");
    eprintln!("shutdown complete");
    Ok(())
}

/// `tmi serve --node-id <id>`: a cluster serving node. Starts empty
/// (routes arrive as `replicate` pushes from the control plane) or
/// pre-seeded from `--model`; everything else on the port is the
/// ordinary line protocol.
fn cmd_serve_node(args: &Args) -> Result<()> {
    let id = args.get("node-id").unwrap().to_string();
    if args.get("registry").is_some() {
        bail!(
            "--node-id and --registry are mutually exclusive: in cluster mode the \
             control plane owns the registry and replicates it to nodes"
        );
    }
    if args.has_flag("feedback") || args.has_flag("watch") {
        bail!(
            "--node-id is incompatible with --feedback/--watch: the control \
             plane is the route publisher in cluster mode"
        );
    }
    let workers: usize = args.parse_or("workers", 1)?;
    let queue_cap: usize = args.parse_or("queue-cap", 1024)?;
    let route_config = RouteConfig {
        policy: BatchPolicy::default(),
        workers,
        queue_cap,
        ..RouteConfig::default()
    };
    let mut coord = Coordinator::new();
    if let Some(model_path) = args.get("model") {
        let mut tm = io::load(model_path)?;
        if let Some(simd) = parse_simd_mode(args)? {
            tm.set_simd(simd);
        }
        let infer_mode = parse_infer_mode(args)?;
        let snap = Arc::new(ModelSnapshot::with_mode(tm, 1, infer_mode));
        coord.register_model("cpu", snap, route_config);
        eprintln!("node '{id}': pre-seeded route 'cpu' from {model_path}");
    }
    let mut node_opts = NodeOptions::new(id.as_str());
    node_opts.route_config = route_config;
    let node = Arc::new(NodeState::new(coord, node_opts));
    let listen = args.get_or("listen", "127.0.0.1:7070");
    let listener =
        std::net::TcpListener::bind(&listen).with_context(|| format!("binding {listen}"))?;
    let opts = parse_serve_options(args)?;
    let handle = node.handle();
    let stop = shutdown_flag();
    setup_observability(args, &handle, &stop, opts)?;
    eprintln!(
        "cluster node '{id}' on {listen}: {} route(s); replication protocol live \
         ({} worker(s)/route, queue bound {queue_cap})",
        handle.models().len(),
        workers.max(1),
    );
    serve_node(listener, Arc::clone(&node), Arc::clone(&stop), opts)?;
    eprintln!("shutdown: stopped accepting; draining queues");
    node.shutdown();
    dump_journal_on_shutdown("node serve loop stopped");
    eprintln!("shutdown complete");
    Ok(())
}

/// `tmi control`: the cluster control plane — heartbeat every node,
/// evict on missed beats, re-admit on recovery, replicate the
/// registry's published snapshots to each route's owners, and serve
/// the `cluster` / `metrics` verbs.
fn cmd_control(args: &Args) -> Result<()> {
    let nodes = NodeSpec::parse_list(
        args.get("nodes")
            .context("--nodes id@host:port[,id@host:port ...] required")?,
    )
    .map_err(anyhow::Error::msg)?;
    let dir = PathBuf::from(
        args.get("registry")
            .context("--registry <dir> required (the replication source)")?,
    );
    let mut cfg = ControlConfig::new(nodes, dir.clone());
    cfg.heartbeat = std::time::Duration::from_millis(args.parse_or("heartbeat-ms", 500u64)?);
    cfg.miss_threshold = args.parse_or("miss-threshold", 3u32)?;
    cfg.replicas = args.parse_or("replicas", 2usize)?;
    cfg.probe_timeout =
        std::time::Duration::from_millis(args.parse_or("probe-timeout-ms", 500u64)?);
    ensure!(cfg.miss_threshold >= 1, "--miss-threshold must be at least 1");
    ensure!(cfg.replicas >= 1, "--replicas must be at least 1");
    let listen = args.get_or("listen", "127.0.0.1:7090");
    let listener =
        std::net::TcpListener::bind(&listen).with_context(|| format!("binding {listen}"))?;
    let mut plane = ControlPlane::new(cfg.clone());
    let view = plane.shared_view();
    let stop = shutdown_flag();
    let stop_plane = Arc::clone(&stop);
    let runner = std::thread::Builder::new()
        .name("tmi-control".into())
        .spawn(move || plane.run(&stop_plane))
        .context("spawning control-plane thread")?;
    eprintln!(
        "control plane on {listen}: {} node(s), replicas={}, heartbeat {}ms, \
         evict after {} missed beat(s), registry {}",
        cfg.nodes.len(),
        cfg.replicas,
        cfg.heartbeat.as_millis(),
        cfg.miss_threshold,
        dir.display(),
    );
    serve_control(listener, view, Arc::clone(&stop))?;
    stop.store(true, Ordering::SeqCst);
    runner.join().ok();
    dump_journal_on_shutdown("control plane stopped");
    eprintln!("shutdown complete");
    Ok(())
}

/// `tmi route`: the request router — forwards client lines to the
/// owning node with a per-request deadline, backed-off failover across
/// replicas, and `err unavailable` degradation.
fn cmd_route(args: &Args) -> Result<()> {
    let static_nodes = match args.get("nodes") {
        Some(spec) => NodeSpec::parse_list(spec).map_err(anyhow::Error::msg)?,
        None => Vec::new(),
    };
    let control = args.get("control").map(str::to_string);
    ensure!(
        control.is_some() || !static_nodes.is_empty(),
        "--nodes id@host:port,... and/or --control host:port required"
    );
    let mut cfg = RouterConfig::new(static_nodes);
    cfg.control = control;
    cfg.deadline = std::time::Duration::from_millis(args.parse_or("deadline-ms", 2000u64)?);
    cfg.poll = std::time::Duration::from_millis(args.parse_or("poll-ms", 500u64)?);
    let listen = args.get_or("listen", "127.0.0.1:7080");
    let listener =
        std::net::TcpListener::bind(&listen).with_context(|| format!("binding {listen}"))?;
    let router = Arc::new(Router::new(cfg.clone()));
    // seed membership from the control plane before accepting traffic
    // (a failed first poll just keeps the static seed)
    router.poll_membership();
    let stop = shutdown_flag();
    if cfg.control.is_some() {
        let poll_router = Arc::clone(&router);
        let stop_poll = Arc::clone(&stop);
        std::thread::Builder::new()
            .name("tmi-route-poll".into())
            .spawn(move || poll_router.run_membership_poll(&stop_poll))
            .context("spawning membership poll thread")?;
    }
    eprintln!(
        "router on {listen}: deadline {}ms, membership {}",
        cfg.deadline.as_millis(),
        match &cfg.control {
            Some(c) => format!("polled from control plane {c} every {}ms", cfg.poll.as_millis()),
            None => format!("static ({} node(s))", cfg.nodes.len()),
        },
    );
    serve_router(listener, router, Arc::clone(&stop))?;
    dump_journal_on_shutdown("router stopped");
    eprintln!("shutdown complete");
    Ok(())
}

/// File stamp used by `--watch` to detect republishes: (length, CRC-32
/// of the contents). A *content* digest — not (mtime, length) — so a
/// same-length rewrite landing within one mtime granule still
/// registers, and a rewrite of identical bytes doesn't trigger a
/// pointless swap.
fn model_file_stamp(path: &str) -> Option<(u64, u32)> {
    let bytes = std::fs::read(path).ok()?;
    Some((bytes.len() as u64, tsetlin_index::util::crc32(&bytes)))
}

/// Poll `path`; on change, reload the model and hot-swap route `cpu`
/// to the next version (keeping the route's configured engine
/// selection policy). `io::save` writes atomically (tmp + rename),
/// so a reload never sees a torn file; a failed load (e.g. an external
/// writer without the atomic protocol) keeps the old version serving.
fn watch_model_file(
    path: &str,
    handle: tsetlin_index::coordinator::CoordinatorHandle,
    interval: std::time::Duration,
    infer_mode: InferMode,
    simd: Option<SimdMode>,
    stop: Arc<AtomicBool>,
) {
    let mut last = model_file_stamp(path);
    while !stop.load(Ordering::Relaxed) {
        std::thread::sleep(interval);
        let cur = model_file_stamp(path);
        if cur.is_none() || cur == last {
            continue;
        }
        // Versioning fix: snapshot versions are *publisher-scoped*
        // (a Trainer's publish_seq restarts at 1), so a thread-local
        // counter here can collide with or regress behind what another
        // publisher installed. Key the successor off the route's
        // current serving version instead — the swap generation in
        // `stats` stays the cross-publisher monotonic witness.
        let served = handle.stats("cpu").and_then(|s| s.version).unwrap_or(0);
        match io::load(path) {
            Ok(mut tm) => {
                // keep the serve command's --simd override sticky
                // across reloads (the file carries its own mode)
                if let Some(simd) = simd {
                    tm.set_simd(simd);
                }
                let version = served + 1;
                let snap = Arc::new(ModelSnapshot::with_mode(tm, version, infer_mode));
                match handle.swap("cpu", snap) {
                    Ok(retired) => {
                        journal().emit(EventKind::WatchReload {
                            route: "cpu".to_string(),
                            version,
                        });
                        eprintln!("watch: hot-swapped 'cpu' v{retired} -> v{version}")
                    }
                    Err(e) => {
                        journal().emit(EventKind::WatchFallback {
                            route: "cpu".to_string(),
                            error: e.to_string(),
                        });
                        eprintln!("watch: swap refused ({e}); keeping v{served}");
                    }
                }
                last = cur;
            }
            Err(e) => {
                // transient (mid-write by a non-atomic writer) or real
                // corruption: keep serving the old version either way
                journal().emit(EventKind::WatchFallback {
                    route: "cpu".to_string(),
                    error: format!("{e:#}"),
                });
                eprintln!("watch: reload of {path} failed ({e:#}); keeping v{served}");
            }
        }
    }
}

/// `tmi serve --registry <dir>`: rebuild every route from the registry
/// manifest alone (crash recovery), then serve. Damaged snapshot files
/// are quarantined on the way to the newest intact version; a route
/// with no intact version is skipped with a warning instead of taking
/// the server down. `--watch` polls the manifest *generation* — not
/// file mtimes — so external publishers (`tmi train --registry`) are
/// picked up even when a rewrite preserves length and mtime.
fn cmd_serve_registry(args: &Args) -> Result<()> {
    if args.get("model").is_some() {
        bail!("--registry and --model are mutually exclusive (the manifest names the models)");
    }
    if args.get_or("backend", "indexed") != "indexed" {
        bail!("--registry serves snapshot routes (indexed backend); ablations need --model");
    }
    let dir = PathBuf::from(args.get("registry").unwrap());
    let retain: usize = args.parse_or("retain", DEFAULT_RETAIN)?;
    let workers: usize = args.parse_or("workers", 1)?;
    let queue_cap: usize = args.parse_or("queue-cap", 1024)?;
    // explicit --simd overrides whatever mode each published model
    // carries (applied to every recovered route below)
    let simd_override = parse_simd_mode(args)?;
    let mut registry = Registry::open(&dir, retain)?;
    let route_names: Vec<String> = registry.routes().map(|(n, _)| n.to_string()).collect();
    if route_names.is_empty() {
        bail!(
            "registry {} has no routes; publish one with `tmi train ... --registry {} --route <name>`",
            dir.display(),
            dir.display()
        );
    }
    let feedback_on = args.has_flag("feedback");
    if feedback_on && args.has_flag("watch") {
        bail!(
            "--feedback and --watch are mutually exclusive: the online learner \
             is its routes' publisher; an external publisher racing it would \
             overwrite the learner's in-memory updates"
        );
    }
    let online_cfg = parse_online_config(args)?;
    let mut coord = Coordinator::new();
    let mut state = WatchState::default();
    // Routes awaiting a learner thread once the coordinator can hand
    // out publish hooks: (route, recovered+replayed trainer, WAL).
    let mut pending: Vec<(String, Trainer, FeedbackWal, InferMode)> = Vec::new();
    for name in route_names {
        match registry.load_published(&name) {
            Ok(rec) => {
                if !rec.quarantined.is_empty() {
                    eprintln!(
                        "registry: route '{}': quarantined damaged version(s) {:?}",
                        name, rec.quarantined
                    );
                }
                eprintln!(
                    "registry: recovered route '{}' at v{} (infer {})",
                    name,
                    rec.version,
                    rec.infer.name()
                );
                let mut serve_tm = rec.tm;
                if let Some(simd) = simd_override {
                    serve_tm.set_simd(simd);
                }
                let mut serve_version = rec.version;
                if feedback_on {
                    // WAL replay closes the kill -9 window *before* the
                    // route serves: reseed the trainer's RNG streams to
                    // the epoch of the recovered version (the same epoch
                    // the live learner entered when it published it),
                    // apply the logged events in order, then republish
                    // durably so the log can be truncated.
                    let wal_path = FeedbackWal::route_path(&dir.join(&name));
                    let (mut wal, replay) = FeedbackWal::open(&wal_path)
                        .with_context(|| format!("opening feedback WAL {}", wal_path.display()))?;
                    wal.set_sync_on_append(online_cfg.wal_fsync);
                    let mut trainer = Trainer::from_machine(serve_tm.clone(), Backend::Indexed)
                        .with_infer_mode(rec.infer);
                    let base_seed = trainer.tm.params.seed;
                    trainer.reseed_streams(reseed_seed(base_seed, serve_version));
                    if replay.truncated_bytes > 0 {
                        eprintln!(
                            "registry: route '{name}': dropped {} byte(s) of torn WAL tail",
                            replay.truncated_bytes
                        );
                    }
                    if !replay.records.is_empty() {
                        let summary =
                            replay_feedback(&mut trainer, &replay.records, serve_version);
                        journal().emit(EventKind::WalReplay {
                            route: name.clone(),
                            records: summary.applied,
                            stale: summary.stale,
                            skipped: summary.skipped,
                        });
                        if summary.stale > 0 {
                            eprintln!(
                                "registry: route '{name}': skipped {} WAL record(s) already \
                                 owned by recovered v{serve_version} (publish-before-truncate \
                                 crash window; benign)",
                                summary.stale
                            );
                        }
                        if summary.skipped > 0 {
                            eprintln!(
                                "registry: route '{name}': WARNING: skipped {} foreign/corrupt \
                                 WAL record(s) (bad label or literal width) in {} — is this \
                                 another route's log?",
                                summary.skipped,
                                wal_path.display()
                            );
                        }
                        if summary.applied > 0 {
                            let v = registry.publish(&name, &trainer.tm, rec.infer)?;
                            wal.truncate().with_context(|| {
                                format!("truncating replayed WAL {}", wal_path.display())
                            })?;
                            trainer.reseed_streams(reseed_seed(base_seed, v));
                            eprintln!(
                                "registry: route '{name}': replayed {} feedback record(s) \
                                 from WAL -> published v{v}",
                                summary.applied
                            );
                            serve_tm = trainer.tm.clone();
                            serve_version = v;
                        } else if summary.skipped == 0 {
                            // every record is owned by the recovered
                            // snapshot: retry the truncate the crash
                            // interrupted — no republish needed
                            wal.truncate().with_context(|| {
                                format!("truncating stale WAL {}", wal_path.display())
                            })?;
                        }
                        // foreign-only logs are left in place (evidence
                        // for the operator); the learner's next durable
                        // publish truncates them
                    }
                    wal.set_version(serve_version);
                    pending.push((name.clone(), trainer, wal, rec.infer));
                }
                let snap = Arc::new(ModelSnapshot::with_mode(serve_tm, serve_version, rec.infer));
                coord.register_model(
                    &name,
                    snap,
                    RouteConfig {
                        policy: BatchPolicy::default(),
                        workers,
                        queue_cap,
                        ..RouteConfig::default()
                    },
                );
                state.served.insert(name, serve_version);
            }
            Err(e) => {
                // surviving routes keep serving; this one needs a
                // republish (picked up live when --watch is on)
                eprintln!("registry: route '{name}' not recovered ({e}); skipping");
            }
        }
    }
    ensure!(
        !state.served.is_empty(),
        "no route in registry {} could be recovered",
        dir.display()
    );
    state.generation = registry.generation();
    let registry = Arc::new(Mutex::new(registry));
    // Spawn learners before any serving handle is created: handles
    // capture each route's feedback sender at handle() time. Durable
    // publish hook: registry-persist the trainer's machine (the
    // registry version *is* the snapshot version — the cross-restart
    // key), hot-swap it, and report durable so the learner truncates
    // the WAL and advances its RNG epoch.
    let mut learners: Vec<OnlineLearner> = Vec::new();
    for (name, trainer, wal, infer) in pending {
        let hook = coord.handle();
        let reg = Arc::clone(&registry);
        let route = name.clone();
        let publish: PublishFn = Box::new(move |tr: &mut Trainer, _updates: u64| {
            let version = reg
                .lock()
                .unwrap_or_else(PoisonError::into_inner)
                .publish(&route, &tr.tm, infer)
                .map_err(|e| e.to_string())?;
            let snap = Arc::new(ModelSnapshot::with_mode(tr.tm.clone(), version, infer));
            hook.swap(&route, snap).map_err(|e| e.to_string())?;
            let generation = hook.stats(&route).and_then(|s| s.generation).unwrap_or(0);
            Ok(PublishReport {
                version,
                generation,
                durable: true,
            })
        });
        let metrics = coord
            .route_metrics(&name)
            .expect("recovered route is registered");
        let l = OnlineLearner::spawn(name.clone(), trainer, Some(wal), publish, metrics, online_cfg);
        coord
            .attach_learner(&name, l.sender())
            .map_err(|e| anyhow::anyhow!("attaching learner to '{name}': {e}"))?;
        eprintln!(
            "online learner on '{name}': publish every {} update(s) / {} ms \
             (durable: WAL-first feedback, truncated at each registry publish)",
            online_cfg.publish_every,
            online_cfg
                .publish_interval
                .map(|i| i.as_millis().to_string())
                .unwrap_or_else(|| "off".into()),
        );
        learners.push(l);
    }
    let listen = args.get_or("listen", "127.0.0.1:7070");
    let listener =
        std::net::TcpListener::bind(&listen).with_context(|| format!("binding {listen}"))?;
    eprintln!(
        "serving registry routes {:?} on {listen} ({} worker(s)/route, queue bound {})",
        coord.models(),
        workers.max(1),
        queue_cap,
    );
    let opts = parse_serve_options(args)?;
    let handle = coord.handle();
    let stop = shutdown_flag();
    setup_observability(args, &handle, &stop, opts)?;
    if args.has_flag("watch") {
        let interval =
            std::time::Duration::from_millis(args.parse_or("watch-interval-ms", 500u64)?);
        let watch_handle = handle.clone();
        let watch_registry_arc = Arc::clone(&registry);
        let stop_watch = Arc::clone(&stop);
        let watch_dir = dir.clone();
        std::thread::Builder::new()
            .name("tmi-watch".into())
            .spawn(move || {
                watch_registry(
                    &watch_dir,
                    retain,
                    watch_registry_arc,
                    state,
                    watch_handle,
                    interval,
                    stop_watch,
                )
            })
            .expect("spawning watch thread");
        eprintln!(
            "watching {} (poll {}ms): hot-swapping routes on manifest generation change",
            dir.display(),
            interval.as_millis()
        );
    }
    serve_tcp_with(listener, handle, Arc::clone(&stop), opts)?;
    eprintln!("shutdown: stopped accepting; draining queues");
    for l in learners {
        // final durable publish of any pending feedback: a clean drain
        // leaves nothing only-in-WAL
        l.shutdown();
    }
    coord.shutdown();
    dump_journal_on_shutdown("registry serve loop stopped");
    let flushed = registry
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
        .flush();
    match flushed {
        Ok(()) => eprintln!("shutdown: registry manifest flushed; exiting"),
        Err(e) => eprintln!("shutdown: manifest flush failed ({e}); on-disk state is still the last stored generation"),
    }
    Ok(())
}

/// Poll the registry manifest generation; on change, reload the
/// manifest from disk (an external `tmi train --registry` publisher
/// moved it) and reconcile every route: recover the published version
/// and hot-swap it in. Failures (damage quarantined down to nothing,
/// swap refusal) leave the route serving its current version.
fn watch_registry(
    dir: &Path,
    retain: usize,
    registry: Arc<Mutex<Registry>>,
    mut state: WatchState,
    handle: tsetlin_index::coordinator::CoordinatorHandle,
    interval: std::time::Duration,
    stop: Arc<AtomicBool>,
) {
    while !stop.load(Ordering::Relaxed) {
        std::thread::sleep(interval);
        let Some(generation) = read_generation(dir) else {
            continue; // manifest unreadable mid-write: retry next poll
        };
        if generation == state.generation {
            continue;
        }
        let mut guard = registry.lock().unwrap_or_else(PoisonError::into_inner);
        match Registry::open(dir, retain) {
            Ok(reloaded) => *guard = reloaded,
            Err(e) => {
                eprintln!("watch: manifest reload failed ({e}); keeping served versions");
                continue;
            }
        }
        let events = sync_published(&mut guard, &mut state, |route, rec| {
            let snap = Arc::new(ModelSnapshot::with_mode(rec.tm.clone(), rec.version, rec.infer));
            handle.swap(route, snap).map(drop).map_err(|e| e.to_string())
        });
        drop(guard);
        for ev in events {
            match ev {
                SyncEvent::Published {
                    route,
                    version,
                    quarantined,
                } => {
                    if quarantined.is_empty() {
                        eprintln!("watch: route '{route}' -> v{version}");
                    } else {
                        eprintln!(
                            "watch: route '{route}' -> v{version} (quarantined {quarantined:?})"
                        );
                    }
                }
                SyncEvent::Failed { route, error } => {
                    eprintln!("watch: route '{route}' kept on its serving version ({error})");
                }
            }
        }
    }
}

/// Serve-side observability wiring shared by `--model` and
/// `--registry` serving: `--obs off` disables per-request stage
/// clocking (probes and the journal stay on — they are batch-wise and
/// event-wise, not per-request), and `--metrics-addr host:port` starts
/// the Prometheus text-exposition listener on its own thread.
fn setup_observability(
    args: &Args,
    handle: &tsetlin_index::coordinator::CoordinatorHandle,
    stop: &Arc<AtomicBool>,
    opts: ServeOptions,
) -> Result<()> {
    match args.get_or("obs", "on").as_str() {
        "on" => {}
        "off" => {
            obs::set_enabled(false);
            eprintln!("observability: per-request stage tracing disabled (--obs off)");
        }
        other => bail!("bad value for --obs: '{other}' (on|off)"),
    }
    if let Some(addr) = args.get("metrics-addr") {
        let listener = std::net::TcpListener::bind(addr)
            .with_context(|| format!("binding metrics listener {addr}"))?;
        let metrics_handle = handle.clone();
        let stop_metrics = Arc::clone(stop);
        std::thread::Builder::new()
            .name("tmi-metrics".into())
            .spawn(move || {
                if let Err(e) = serve_metrics_http_with(listener, metrics_handle, stop_metrics, opts)
                {
                    eprintln!("metrics listener stopped: {e}");
                }
            })
            .context("spawning metrics thread")?;
        eprintln!("metrics: Prometheus exposition on http://{addr}/metrics");
    }
    Ok(())
}

/// Shutdown trail: record the drain in the journal, then dump every
/// retained event to stderr — the post-mortem a `kill -9` would have
/// eaten is at least visible on every clean drain.
fn dump_journal_on_shutdown(reason: &str) {
    journal().emit(EventKind::Drain {
        reason: reason.to_string(),
    });
    let events = journal().snapshot();
    let dropped = journal().dropped();
    eprintln!(
        "journal: {} event(s) retained, {} dropped",
        events.len(),
        dropped
    );
    for e in events {
        eprintln!("journal: {}", e.to_line());
    }
}

/// The serve loop's stop flag, wired to SIGINT/SIGTERM on unix: the
/// handler sets a static; a bridge thread forwards it here so
/// `serve_tcp_with` stops accepting and the caller drains and exits 0.
fn shutdown_flag() -> Arc<AtomicBool> {
    let stop = Arc::new(AtomicBool::new(false));
    #[cfg(unix)]
    {
        sig::install();
        let stop_bridge = Arc::clone(&stop);
        std::thread::Builder::new()
            .name("tmi-signals".into())
            .spawn(move || loop {
                if sig::SHUTDOWN.load(Ordering::SeqCst) {
                    eprintln!("shutdown: signal received");
                    stop_bridge.store(true, Ordering::SeqCst);
                    return;
                }
                if stop_bridge.load(Ordering::SeqCst) {
                    return;
                }
                std::thread::sleep(std::time::Duration::from_millis(25));
            })
            .expect("spawning signal bridge thread");
    }
    stop
}

/// Minimal libc-free signal hookup (the offline build has no signal
/// crate): `signal(2)` registers a handler that only stores an atomic
/// flag (async-signal-safe); [`shutdown_flag`]'s bridge thread does
/// everything else outside signal context.
#[cfg(unix)]
mod sig {
    use std::sync::atomic::{AtomicBool, Ordering};

    pub static SHUTDOWN: AtomicBool = AtomicBool::new(false);

    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }

    extern "C" fn on_signal(_signum: i32) {
        SHUTDOWN.store(true, Ordering::SeqCst);
    }

    /// Install the handler for SIGINT (2) and SIGTERM (15).
    pub fn install() {
        unsafe {
            signal(2, on_signal as extern "C" fn(i32) as usize);
            signal(15, on_signal as extern "C" fn(i32) as usize);
        }
    }
}

/// `tmi registry <ls|verify|gc>` — inspect and maintain a registry
/// directory without serving it.
fn cmd_registry(action: &str, args: &Args) -> Result<()> {
    let dir = args
        .get("registry")
        .or_else(|| args.get("dir"))
        .context("--registry <dir> required")?;
    let retain: usize = args.parse_or("retain", DEFAULT_RETAIN)?;
    match action {
        "ls" => {
            let registry = Registry::open(dir, retain)?;
            println!(
                "registry {} (generation {})",
                registry.dir().display(),
                registry.generation()
            );
            for (name, entry) in registry.routes() {
                let versions: Vec<String> = entry
                    .versions
                    .iter()
                    .map(|v| format!("v{}:{}B", v.version, v.bytes))
                    .collect();
                println!(
                    "  {name}  published=v{}  infer={}  versions=[{}]",
                    entry.published,
                    entry.infer.name(),
                    versions.join(" ")
                );
            }
            Ok(())
        }
        "verify" => {
            let registry = Registry::open(dir, retain)?;
            let issues = registry.verify();
            for i in &issues {
                println!("DAMAGED {}/v{} ({}): {}", i.route, i.version, i.file, i.why);
            }
            ensure!(
                issues.is_empty(),
                "{} damaged snapshot file(s)",
                issues.len()
            );
            println!("ok: every recorded snapshot matches its digest");
            Ok(())
        }
        "gc" => {
            let mut registry = Registry::open(dir, retain)?;
            let report = registry.gc()?;
            println!(
                "gc: pruned {} version(s), removed {} unreferenced file(s)",
                report.pruned_versions, report.removed_files
            );
            Ok(())
        }
        other => bail!("unknown registry action '{other}' (ls|verify|gc)"),
    }
}

fn cmd_loadgen(args: &Args) -> Result<()> {
    let targets: Vec<String> = args
        .get("targets")
        .map(|t| {
            t.split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(str::to_string)
                .collect()
        })
        .unwrap_or_default();
    let cfg = LoadgenConfig {
        addr: args.get_or("addr", "127.0.0.1:7070"),
        targets,
        model: args.get_or("model", "cpu"),
        connections: args.parse_or("connections", 4)?,
        rate: args.parse_or("rate", 0.0)?,
        duration: std::time::Duration::from_secs_f64(args.parse_or("duration", 10.0)?),
        features: args
            .get("features")
            .context("--features required (the model's raw feature width)")?
            .parse()
            .map_err(|_| anyhow::anyhow!("bad value for --features"))?,
        seed: args.parse_or("seed", 42)?,
        feedback_rate: args.parse_or("feedback-rate", 0.0)?,
        classes: args.parse_or("classes", 2)?,
    };
    eprintln!(
        "loadgen: {} loop, {} connection(s){} for {:.1}s against {} (model '{}')",
        if cfg.rate > 0.0 { "open" } else { "closed" },
        cfg.connections,
        if cfg.rate > 0.0 {
            format!(" at {:.0} req/s total", cfg.rate)
        } else {
            String::new()
        },
        cfg.duration.as_secs_f64(),
        if cfg.targets.is_empty() {
            cfg.addr.clone()
        } else {
            cfg.targets.join(",")
        },
        cfg.model,
    );
    let report = tsetlin_index::coordinator::loadgen::run(&cfg)?;
    println!("{}", report.summary());
    if let Some(stats) = &report.server_stats {
        println!("server: {stats}");
    }
    let out = args.get_or("out", "BENCH_serve.json");
    tsetlin_index::bench_harness::report::write_json(Path::new(&out), &report.to_json(&cfg))?;
    eprintln!("wrote {out}");
    if let Some(min_ok) = args.get("assert-min-ok") {
        let min_ok: u64 = min_ok
            .parse()
            .map_err(|_| anyhow::anyhow!("bad value for --assert-min-ok"))?;
        anyhow::ensure!(
            report.ok >= min_ok,
            "completed requests {} below floor {min_ok}",
            report.ok
        );
    }
    if let Some(max_shed) = args.get("assert-max-shed-rate") {
        let max_shed: f64 = max_shed
            .parse()
            .map_err(|_| anyhow::anyhow!("bad value for --assert-max-shed-rate"))?;
        anyhow::ensure!(
            report.shed_rate <= max_shed,
            "shed rate {:.4} above ceiling {max_shed}",
            report.shed_rate
        );
    }
    anyhow::ensure!(
        report.errors == 0,
        "{} requests failed with non-overload errors",
        report.errors
    );
    // Hot-swap safety gate for the mixed infer+feedback phase: every
    // reply intact, and the route's swap generation (the
    // cross-publisher monotonic key — snapshot *versions* are
    // publisher-scoped and may repeat across restarts) moved forward.
    if args.has_flag("assert-monotone-generations") {
        anyhow::ensure!(
            report.torn == 0,
            "{} torn repl(ies) observed under live publishing",
            report.torn
        );
        let start = report
            .generation_start
            .context("no route generation before the run (stats unavailable?)")?;
        let end = report
            .generation_end
            .context("no route generation after the run (stats unavailable?)")?;
        anyhow::ensure!(
            end >= start,
            "route generation went backwards: {start} -> {end}"
        );
        if report.feedback_ok > 0 {
            anyhow::ensure!(
                end > start,
                "{} feedback updates applied but the route generation never \
                 advanced ({start} -> {end}); is the server publishing?",
                report.feedback_ok
            );
        }
    }
    // Observability overhead gate: compare this (instrumented) run's
    // throughput against a prior `--obs off` baseline BENCH_serve.json.
    // The comparison always prints; it only *fails* the run when
    // TMI_ASSERT_MAX_OBS_OVERHEAD is set (CI — mirrors the
    // TMI_ASSERT_MIN_TEST_SPEEDUP bench-gate convention).
    if let Some(baseline_path) = args.get("baseline") {
        let raw = std::fs::read_to_string(baseline_path)
            .with_context(|| format!("reading baseline {baseline_path}"))?;
        let base = tsetlin_index::util::Json::parse(&raw)
            .map_err(|e| anyhow::anyhow!("parsing baseline {baseline_path}: {e}"))?;
        let base_rps = base
            .get("throughput_rps")
            .and_then(|v| v.as_f64())
            .context("baseline has no throughput_rps")?;
        anyhow::ensure!(base_rps > 0.0, "baseline throughput is zero");
        let overhead = (base_rps - report.throughput_rps) / base_rps;
        eprintln!(
            "obs overhead check: baseline {base_rps:.0} ok/s, instrumented {:.0} ok/s \
             ({:+.2}% overhead)",
            report.throughput_rps,
            overhead * 100.0
        );
        if let Ok(raw) = std::env::var("TMI_ASSERT_MAX_OBS_OVERHEAD") {
            let max: f64 = raw
                .parse()
                .map_err(|_| anyhow::anyhow!("TMI_ASSERT_MAX_OBS_OVERHEAD must be a float"))?;
            anyhow::ensure!(
                overhead <= max,
                "instrumented throughput fell {:.2}% below the --obs off baseline \
                 (ceiling {:.2}%)",
                overhead * 100.0,
                max * 100.0
            );
        }
    }
    Ok(())
}

/// `tmi promcheck` — validate a Prometheus text exposition against the
/// strict structural checker the test suite uses. Reads `--file PATH`
/// or stdin, so CI can pipe a live scrape straight through:
/// `curl -s http://<metrics-addr>/metrics | tmi promcheck`.
fn cmd_promcheck(args: &Args) -> Result<()> {
    let text = match args.get("file") {
        Some(path) => {
            std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?
        }
        None => {
            use std::io::Read;
            let mut buf = String::new();
            std::io::stdin()
                .read_to_string(&mut buf)
                .context("reading exposition from stdin")?;
            buf
        }
    };
    ensure!(!text.trim().is_empty(), "empty exposition (nothing to check)");
    match tsetlin_index::obs::prometheus::validate_exposition(&text) {
        Ok(()) => {
            let samples = text
                .lines()
                .filter(|l| !l.is_empty() && !l.starts_with('#'))
                .count();
            println!("ok: conformant exposition ({samples} sample line(s))");
            Ok(())
        }
        Err(why) => bail!("exposition not conformant: {why}"),
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    match Runtime::cpu() {
        Ok(rt) => println!("pjrt platform: {}", rt.platform()),
        Err(e) => println!("pjrt unavailable: {e:#}"),
    }
    let dir = args.get_or("artifacts", "artifacts");
    match Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts in {dir}:");
            for v in &m.variants {
                println!(
                    "  {:<36} batch={:<3} features={:<5} clauses={:<6} classes={:<2} fused={}",
                    v.name, v.batch, v.features, v.clauses, v.classes, v.fused
                );
            }
        }
        Err(e) => println!("no artifact manifest in {dir}: {e:#}"),
    }
    Ok(())
}

const USAGE: &str = "usage: tmi <train|eval|table|work-ratio|serve|control|route|loadgen|promcheck|registry|info> [--key value ...]
  train      --dataset mnist|fashion|imdb [--levels N|--features N] --clauses N
             --epochs N [--backend naive|bitpacked|indexed] [--out model.tm]
             [--registry DIR [--route NAME] [--retain K]]  (publish the trained
                             model as the route's next registry version)
             [--samples N] [--data-dir DIR] [--threshold T] [--s S] [--seed N]
             [--weighted]   (integer clause weights, paper ref [8])
             [--threads N]  (clause-sharded parallel training; 1 = sequential,
                             0 = every available core; indexed backend only)
             [--stale-window N]  (samples between worker syncs, default 8;
                                  vote sums are read up to N samples stale)
             [--infer auto|dense|sparse]  (indexed-backend inference engine:
                             dense class-fused walk or O(nnz) sparse-delta
                             walk; auto picks by input density)
             [--ta-layout sliced|scalar]  (TA storage: bit-sliced banks with
                             word-parallel feedback (default) or the portable
                             scalar escape hatch; bit-identical training)
             [--simd auto|wide|scalar]  (lane width for the bit-plane hot
                             loops: wide = 4-lane u64 kernels with runtime
                             AVX2/POPCNT dispatch, scalar = reference loops,
                             auto (default) = wide where the clause-plane
                             budget fits; bit-identical either way, see
                             docs/TUNING.md)
  eval       --model model.tm --dataset ... [--backend B] [--threads N]
             [--infer auto|dense|sparse] [--simd auto|wide|scalar]
  table      --id 1|2|3 [--scale quick|standard|paper] [--out-dir results/]
  work-ratio --dataset ... --clauses N [--epochs N]
  serve      --model model.tm | --registry DIR  [--artifacts artifacts/]
             [--listen host:port]
             [--registry DIR] (recover every route from the manifest: damaged
                               snapshots are checksum-quarantined, surviving
                               routes serve; SIGTERM/SIGINT drain and exit 0)
             [--retain K]     (registry versions kept per route, default 4)
             [--workers N]    (batcher workers sharing the route queue;
                               indexed backend, hot-swappable snapshot route)
             [--queue-cap N]  (admission bound per route; beyond it requests
                               are shed with 'err overloaded'; default 1024)
             [--max-conns N]  (TCP connection cap, reaped pool; default 256)
             [--read-timeout-ms N]   (per-connection read timeout, default 100)
             [--scrape-timeout-ms N] (metrics scrape head timeout, default 500)
             [--feedback]     (online learning: 'feedback <model> <label> <bits>'
                               and 'train <model> <label>:<bits> ...' verbs apply
                               labeled examples through the clause index's O(1)
                               update hooks on a single-writer learner thread;
                               with --registry the events are WAL-logged before
                               apply and replayed on restart)
             [--publish-every N]      (republish after N applied updates;
                                       0 = off; default 64)
             [--publish-interval MS]  (republish after MS ms with updates
                                       pending; 0 = off; default 500)
             [--feedback-queue-cap N] (feedback admission bound, default 1024)
             [--drift-window N]       (recent-accuracy window, default 256)
             [--wal-fsync]    (fsync each feedback WAL append before the ack:
                               survive power loss, not just kill -9; default
                               off — publishes always sync the log)
             [--watch]        (hot-swap on change, zero downtime: with --model,
                               poll the file's content digest; with --registry,
                               poll the manifest generation; exclusive with
                               --feedback — the learner is the publisher)
             [--watch-interval-ms N]   (poll period, default 500)
             [--infer auto|dense|sparse]
             [--simd auto|wide|scalar]  (override the lane width stored in
                               the model file; sticky across --watch reloads)
             [--backend B] [--parallel N]  (ablation backends serve through a
                               single-worker factory route; no hot swap)
             [--metrics-addr host:port]  (Prometheus text exposition via HTTP
                               GET /metrics; also available as the TCP verb
                               'metrics' on the main listener)
             [--obs on|off]   (per-request stage tracing; off removes the
                               per-request clock reads, keeping batch-wise
                               probes and the event journal; default on)
             [--node-id ID]   (cluster node mode: adds 'ping' liveness and
                               'replicate' snapshot pushes to the protocol;
                               starts empty — or pre-seeded via --model — and
                               receives routes from `tmi control`; exclusive
                               with --registry/--feedback/--watch)
  control    --nodes id@host:port,...  --registry DIR  [--listen host:port]
             (cluster control plane: heartbeats every node, evicts after
              --miss-threshold missed beats, re-admits on recovery, and
              replicates each route's published registry snapshot — the
              checksummed v3 image, CRC-verified again on the node — to its
              --replicas owners on the consistent-hash ring; serves the
              'cluster', 'ping', and per-node-label 'metrics' verbs)
             [--heartbeat-ms N]    (probe cadence, default 500)
             [--miss-threshold N]  (missed beats before eviction, default 3)
             [--replicas N]        (owners per route, default 2)
             [--probe-timeout-ms N] (per-probe timeout, default 500)
  route      [--nodes id@host:port,...] [--control host:port]
             [--listen host:port]
             (request router: forwards protocol lines to the route's owning
              node, retrying the next replica with capped exponential backoff
              on connect failure / timeout / 'err busy'; degrades to a
              complete 'err unavailable' line when every replica is down —
              never a hang, never a torn reply. Membership is polled from
              --control when given (last-known assignment keeps serving
              through a control-plane partition), else static --nodes)
             [--deadline-ms N]  (whole-request deadline, default 2000)
             [--poll-ms N]      (membership poll cadence, default 500)
  loadgen    --features N (model's raw feature width) [--addr host:port]
             [--targets host:port,...]  (cluster mode: spread closed-loop
                               connections across nodes; a connection whose
                               node dies fails over to the next target and
                               the run continues — reported as failovers=N)
             [--model cpu] [--connections N] [--duration SECS]
             [--rate R]   (total offered req/s, open loop; 0 = closed loop)
             [--feedback-rate F]  (fraction of requests sent as 'feedback'
                               with a synthetic label; needs --classes and a
                               server running --feedback; default 0)
             [--classes N]  (label range for --feedback-rate, default 2)
             [--out BENCH_serve.json] [--seed N]
             [--assert-min-ok N] [--assert-max-shed-rate F]   (CI gates)
             [--assert-monotone-generations]  (fail unless the route's swap
                               generation moved forward and no reply was torn)
             [--baseline FILE]  (compare throughput against a prior run's
                               BENCH_serve.json — e.g. an --obs off run; fails
                               when TMI_ASSERT_MAX_OBS_OVERHEAD is exceeded)
  promcheck  [--file FILE]  (validate a Prometheus exposition, else stdin:
                             curl -s http://ADDR/metrics | tmi promcheck)
  registry   <ls|verify|gc> --registry DIR [--retain K]
             ls: routes, published versions, retained files
             verify: re-checksum every recorded snapshot (exit 1 on damage)
             gc: prune to --retain and delete unreferenced snapshot files
  info       [--artifacts artifacts/]";

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        eprintln!("{USAGE}");
        std::process::exit(2);
    };
    // `registry` takes a positional action: tmi registry <ls|verify|gc>
    if cmd == "registry" {
        let Some(action) = argv.get(1).filter(|a| !a.starts_with("--")) else {
            eprintln!("registry needs an action: tmi registry <ls|verify|gc> --registry DIR");
            std::process::exit(2);
        };
        let args = Args::parse(&argv[2..])?;
        if args.has_flag("help") {
            println!("{USAGE}");
            return Ok(());
        }
        return cmd_registry(action, &args);
    }
    let args = Args::parse(&argv[1..])?;
    if args.has_flag("help") {
        println!("{USAGE}");
        return Ok(());
    }
    match cmd.as_str() {
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "table" => cmd_table(&args),
        "work-ratio" => cmd_work_ratio(&args),
        "serve" => cmd_serve(&args),
        "control" => cmd_control(&args),
        "route" => cmd_route(&args),
        "loadgen" => cmd_loadgen(&args),
        "promcheck" => cmd_promcheck(&args),
        "info" => cmd_info(&args),
        other => {
            eprintln!("unknown command '{other}'\n{USAGE}");
            std::process::exit(2);
        }
    }
}
