//! Bit-parallel baseline: packed include-masks, 64 literals per AND.
//!
//! Not in the paper — included as an ablation (DESIGN.md): the indexed
//! evaluator's win over the naive scan is partly "lists skip work" and
//! partly "the naive scan is scalar". This backend isolates the second
//! factor: a clause is falsified iff any word of
//! `include_mask & !literals` is non-zero.
//!
//! The masks are derived state, kept in sync through the [`FlipSink`]
//! hooks — its maintenance cost is one bit-op per flip, cheaper than the
//! index's list surgery.

use crate::eval::traits::{Evaluator, FlipSink};
use crate::tm::bank::ClauseBank;
use crate::util::BitVec;

/// Packed include-mask evaluator.
pub struct BitPackedEval {
    /// One mask of `2o` bits per clause.
    masks: Vec<BitVec>,
    n_literals: usize,
}

impl BitPackedEval {
    /// Build a bit-packed evaluator sized for `params`.
    pub fn new(params: &crate::tm::params::TMParams) -> Self {
        BitPackedEval {
            masks: (0..params.clauses_per_class)
                .map(|_| BitVec::zeros(params.n_literals()))
                .collect(),
            n_literals: params.n_literals(),
        }
    }

    #[inline]
    fn clause_out(&self, j: usize, literals: &BitVec) -> bool {
        let mask_words = self.masks[j].words();
        let lit_words = literals.words();
        debug_assert_eq!(mask_words.len(), lit_words.len());
        for (m, l) in mask_words.iter().zip(lit_words) {
            // included literal that is false -> falsified
            if m & !l != 0 {
                return false;
            }
        }
        true
    }
}

impl FlipSink for BitPackedEval {
    fn on_include(&mut self, j: u32, k: u32, _new_count: u32, _weight: u32) {
        self.masks[j as usize].set(k as usize);
    }
    fn on_exclude(&mut self, j: u32, k: u32, _new_count: u32, _weight: u32) {
        self.masks[j as usize].clear(k as usize);
    }
}

impl Evaluator for BitPackedEval {
    fn score(&mut self, bank: &ClauseBank, literals: &BitVec) -> i32 {
        let mut score = 0;
        for j in 0..bank.clauses() {
            if bank.count(j) > 0 && self.clause_out(j, literals) {
                score += bank.vote(j);
            }
        }
        score
    }

    fn eval_train(&mut self, bank: &ClauseBank, literals: &BitVec, out: &mut BitVec) -> i32 {
        debug_assert_eq!(out.len(), bank.clauses());
        let mut score = 0;
        for j in 0..bank.clauses() {
            let o = self.clause_out(j, literals);
            out.assign(j, o);
            if o {
                score += bank.vote(j);
            }
        }
        score
    }

    fn rebuild(&mut self, bank: &ClauseBank) {
        self.n_literals = bank.n_literals();
        self.masks = (0..bank.clauses())
            .map(|j| {
                let mut m = BitVec::zeros(bank.n_literals());
                for k in bank.included_literals(j) {
                    m.set(k);
                }
                m
            })
            .collect();
    }

    fn name(&self) -> &'static str {
        "bitpacked"
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::traits::reference_score;
    use crate::tm::params::TMParams;
    use crate::util::Rng;

    fn random_setup(
        rng: &mut Rng,
        clauses: usize,
        n_lit: usize,
        density: f64,
    ) -> (ClauseBank, BitPackedEval) {
        let mut b = ClauseBank::new(clauses, n_lit);
        for j in 0..clauses {
            for k in 0..n_lit {
                if rng.bern(density) {
                    b.set_state(j, k, 2);
                }
            }
        }
        let params = TMParams::new(2, clauses, n_lit / 2);
        let mut ev = BitPackedEval::new(&params);
        ev.rebuild(&b);
        (b, ev)
    }

    #[test]
    fn matches_reference_after_rebuild() {
        let mut rng = Rng::new(10);
        for trial in 0..40 {
            let (bank, mut ev) = random_setup(&mut rng, 12, 64, 0.2);
            let lits =
                BitVec::from_bools(&(0..64).map(|_| rng.bern(0.6)).collect::<Vec<_>>());
            assert_eq!(
                ev.score(&bank, &lits),
                reference_score(&bank, &lits, false),
                "trial {trial}"
            );
            let mut out = BitVec::zeros(12);
            assert_eq!(
                ev.eval_train(&bank, &lits, &mut out),
                reference_score(&bank, &lits, true)
            );
        }
    }

    #[test]
    fn flip_hooks_keep_masks_in_sync() {
        let params = TMParams::new(2, 4, 8);
        let mut bank = ClauseBank::new(4, 16);
        let mut ev = BitPackedEval::new(&params);
        // simulate a flip sequence through the hooks + bank together
        bank.set_state(1, 5, 0);
        ev.on_include(1, 5, bank.count(1), 1);
        let mut lits = BitVec::ones(16);
        assert_eq!(ev.score(&bank, &lits), -1); // clause 1 (-) fires
        lits.clear(5);
        assert_eq!(ev.score(&bank, &lits), 0); // falsified
        bank.set_state(1, 5, -1);
        ev.on_exclude(1, 5, bank.count(1), 1);
        assert_eq!(ev.score(&bank, &lits), 0); // empty again
    }

    #[test]
    fn partial_last_word_handled() {
        // 2o = 70: exercises the tail-masking path
        let mut rng = Rng::new(11);
        let (bank, mut ev) = random_setup(&mut rng, 6, 70, 0.3);
        for _ in 0..20 {
            let lits =
                BitVec::from_bools(&(0..70).map(|_| rng.bern(0.5)).collect::<Vec<_>>());
            assert_eq!(ev.score(&bank, &lits), reference_score(&bank, &lits, false));
        }
    }
}
