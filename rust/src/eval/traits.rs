//! The evaluator contract shared by the baselines and the paper's index.

use crate::tm::bank::ClauseBank;
use crate::util::BitVec;

/// Receiver of include/exclude flip events from TA feedback.
///
/// The indexed evaluator maintains its inclusion lists here (the paper's
/// O(1) insert/delete); the bit-parallel baseline keeps its packed masks
/// in sync; the naive baseline ignores flips entirely (it reads TA
/// states directly) — which is exactly why it pays no maintenance
/// overhead, the effect the training tables measure.
pub trait FlipSink {
    /// Literal `k` of clause `j` just became included; `new_count` is
    /// the clause's include-count after the flip, `weight` its current
    /// clause weight (1 for plain TMs).
    fn on_include(&mut self, j: u32, k: u32, new_count: u32, weight: u32);
    /// Literal `k` of clause `j` just became excluded.
    fn on_exclude(&mut self, j: u32, k: u32, new_count: u32, weight: u32);
    /// Clause `j`'s weight changed by `delta` (weighted TMs only);
    /// `nonempty` is whether the clause currently has included literals.
    fn on_weight(&mut self, _j: u32, _delta: i32, _nonempty: bool) {}
}

/// A clause-evaluation strategy for one class's clause bank.
///
/// Both entry points must agree with the reference semantics:
///
/// * **inference** (`score`): clause output is 1 iff the clause is
///   non-empty and none of its included literals is false; the score is
///   the polarity-weighted sum (eq. 2/3 of the paper).
/// * **training** (`eval_train`): identical except *empty clauses output
///   1* (the standard TM learning convention, so fresh clauses can
///   receive Type I feedback); per-clause outputs are materialized into
///   `out` for the feedback step.
pub trait Evaluator: FlipSink {
    /// Inference-mode class score. `&mut self` because implementations
    /// may use internal scratch (generation stamps).
    fn score(&mut self, bank: &ClauseBank, literals: &BitVec) -> i32;

    /// Inference-mode scores for a batch of samples, one entry per
    /// sample. The default loops [`Evaluator::score`], so every backend
    /// participates in the batch serving path; index-based
    /// implementations can override it to reuse walk scratch across the
    /// batch. Must be element-wise identical to calling `score` per
    /// sample. (The class-fused, thread-sharded batch path lives in
    /// [`crate::engine`]; this hook is the single-class building block.)
    fn score_batch(&mut self, bank: &ClauseBank, batch: &[BitVec], out: &mut [i32]) {
        assert_eq!(out.len(), batch.len(), "score_batch output length mismatch");
        for (slot, literals) in out.iter_mut().zip(batch) {
            *slot = self.score(bank, literals);
        }
    }

    /// Training-mode evaluation: fill `out` (length = `bank.clauses()`)
    /// with clause outputs and return the score implied by them.
    fn eval_train(&mut self, bank: &ClauseBank, literals: &BitVec, out: &mut BitVec) -> i32;

    /// Rebuild any derived state from the bank (after model load).
    fn rebuild(&mut self, bank: &ClauseBank);

    /// Backend name (diagnostics).
    fn name(&self) -> &'static str;

    /// Downcast hook (e.g. to reach the index for statistics).
    fn as_any(&self) -> &dyn std::any::Any;
}

/// A sink that drops flips (naive evaluator, tests).
pub struct NoopSink;

impl FlipSink for NoopSink {
    fn on_include(&mut self, _j: u32, _k: u32, _new_count: u32, _weight: u32) {}
    fn on_exclude(&mut self, _j: u32, _k: u32, _new_count: u32, _weight: u32) {}
}

/// Reference scoring used by tests: direct transcription of the trait's
/// documented semantics (weighted votes), shared by every
/// implementation's test module.
pub fn reference_score(bank: &ClauseBank, literals: &BitVec, training: bool) -> i32 {
    let mut score = 0;
    for j in 0..bank.clauses() {
        let empty = bank.count(j) == 0;
        let out = if empty {
            training
        } else {
            bank.included_literals(j).all(|k| literals.get(k))
        };
        if out {
            score += bank.vote(j);
        }
    }
    score
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_score_empty_clause_conventions() {
        let bank = ClauseBank::new(2, 4);
        let lits = BitVec::ones(4);
        // inference: empty clauses vote 0
        assert_eq!(reference_score(&bank, &lits, false), 0);
        // training: empty clauses vote their polarity (+1 - 1 = 0 here)
        assert_eq!(reference_score(&bank, &lits, true), 0);
    }

    #[test]
    fn reference_score_single_clause() {
        let mut bank = ClauseBank::new(2, 4);
        bank.set_state(0, 1, 0); // clause 0 (+) includes literal 1
        let mut lits = BitVec::ones(4);
        assert_eq!(reference_score(&bank, &lits, false), 1);
        lits.clear(1);
        assert_eq!(reference_score(&bank, &lits, false), 0);
    }
}
