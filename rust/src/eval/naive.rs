//! The paper's unindexed baseline: exhaustive TA-action scan.
//!
//! "…the TM must scan through all the actions of the team of TAs
//! responsible for the clause" (§3). Early exit on the first falsifying
//! literal gives the baseline its best case — the paper's §3 Remarks
//! compare against exactly this worst-case-`2o`-per-clause scan.

use crate::eval::traits::{Evaluator, FlipSink};
use crate::tm::bank::ClauseBank;
use crate::util::BitVec;

/// Stateless exhaustive evaluator (reads TA states directly; no derived
/// structures, hence zero maintenance cost during training).
pub struct NaiveEval;

impl NaiveEval {
    /// Build the reference exhaustive-scan evaluator.
    pub fn new(_params: &crate::tm::params::TMParams) -> Self {
        NaiveEval
    }

    /// Clause output: scan the TA actions literal-by-literal; false on
    /// the first included literal that the sample sets to 0. Reads
    /// through the per-literal accessor so the scan is layout-agnostic
    /// (one state read per literal in either TA layout).
    #[inline]
    fn clause_out(bank: &ClauseBank, j: usize, literals: &BitVec) -> bool {
        for k in 0..bank.n_literals() {
            if bank.include(j, k) && !literals.get(k) {
                return false;
            }
        }
        true
    }
}

impl FlipSink for NaiveEval {
    fn on_include(&mut self, _j: u32, _k: u32, _new_count: u32, _weight: u32) {}
    fn on_exclude(&mut self, _j: u32, _k: u32, _new_count: u32, _weight: u32) {}
}

impl Evaluator for NaiveEval {
    fn score(&mut self, bank: &ClauseBank, literals: &BitVec) -> i32 {
        let mut score = 0;
        for j in 0..bank.clauses() {
            if bank.count(j) > 0 && Self::clause_out(bank, j, literals) {
                score += bank.vote(j);
            }
        }
        score
    }

    fn eval_train(&mut self, bank: &ClauseBank, literals: &BitVec, out: &mut BitVec) -> i32 {
        debug_assert_eq!(out.len(), bank.clauses());
        let mut score = 0;
        for j in 0..bank.clauses() {
            // training convention: empty clause outputs 1
            let o = Self::clause_out(bank, j, literals);
            out.assign(j, o);
            if o {
                score += bank.vote(j);
            }
        }
        score
    }

    fn rebuild(&mut self, _bank: &ClauseBank) {}

    fn name(&self) -> &'static str {
        "naive"
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::traits::reference_score;
    use crate::tm::params::TMParams;
    use crate::util::Rng;

    fn random_bank(rng: &mut Rng, clauses: usize, n_lit: usize, density: f64) -> ClauseBank {
        let mut b = ClauseBank::new(clauses, n_lit);
        for j in 0..clauses {
            for k in 0..n_lit {
                if rng.bern(density) {
                    b.set_state(j, k, (rng.below(20) as i8) - 5);
                }
            }
        }
        b
    }

    fn random_lits(rng: &mut Rng, n: usize, p: f64) -> BitVec {
        BitVec::from_bools(&(0..n).map(|_| rng.bern(p)).collect::<Vec<_>>())
    }

    #[test]
    fn matches_reference_on_random_machines() {
        let mut rng = Rng::new(8);
        let params = TMParams::new(2, 10, 16);
        let mut ev = NaiveEval::new(&params);
        for trial in 0..50 {
            let bank = random_bank(&mut rng, 10, 32, 0.3);
            let lits = random_lits(&mut rng, 32, 0.5);
            assert_eq!(
                ev.score(&bank, &lits),
                reference_score(&bank, &lits, false),
                "trial {trial}"
            );
            let mut out = BitVec::zeros(10);
            assert_eq!(
                ev.eval_train(&bank, &lits, &mut out),
                reference_score(&bank, &lits, true),
                "train trial {trial}"
            );
        }
    }

    #[test]
    fn train_outputs_match_clause_semantics() {
        let mut bank = ClauseBank::new(4, 4);
        bank.set_state(0, 0, 0); // clause 0 includes lit 0
        bank.set_state(1, 1, 0); // clause 1 includes lit 1
        let lits = BitVec::from_bools(&[true, false, true, true]);
        let params = TMParams::new(2, 4, 2);
        let mut ev = NaiveEval::new(&params);
        let mut out = BitVec::zeros(4);
        ev.eval_train(&bank, &lits, &mut out);
        assert!(out.get(0)); // satisfied
        assert!(!out.get(1)); // falsified by lit 1
        assert!(out.get(2)); // empty -> 1 in training
        assert!(out.get(3));
    }

    #[test]
    fn empty_machine_scores_zero_at_inference() {
        let bank = ClauseBank::new(6, 8);
        let params = TMParams::new(2, 6, 4);
        let mut ev = NaiveEval::new(&params);
        assert_eq!(ev.score(&bank, &BitVec::ones(8)), 0);
    }
}
