//! Clause evaluation strategies behind a common trait.
//!
//! * [`naive`] — the paper's unindexed comparator: per-clause scan over
//!   all `2o` TA actions, early-exit on the first falsifying literal.
//! * [`bitpacked`] — 64-way bit-parallel scan over packed include-masks;
//!   an "honest modern baseline" ablation the paper does not include.
//!
//! Two index-based paths implement the same semantics elsewhere: the
//! per-class *indexed* evaluator (the paper's contribution, in
//! [`crate::index`]) implements this module's [`Evaluator`] trait, and
//! the batched, class-fused engine (in [`crate::engine`]) scores all
//! classes of a whole batch in one falsification walk per sample. Every
//! path is bit-identical on the same machine; they differ only in speed
//! and maintenance cost.

pub mod bitpacked;
pub mod naive;
pub mod traits;

pub use bitpacked::BitPackedEval;
pub use naive::NaiveEval;
pub use traits::{Evaluator, FlipSink};

use crate::index::IndexedEval;
use crate::tm::params::TMParams;

/// Evaluation backend selector (CLI / bench-harness level).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Exhaustive TA-action scan (paper's baseline).
    Naive,
    /// Bit-parallel include-mask scan (ablation).
    BitPacked,
    /// Inclusion-list + position-matrix index (paper's contribution).
    Indexed,
}

impl Backend {
    /// Construct the evaluator this backend names, sized for `params`.
    pub fn make(self, params: &TMParams) -> Box<dyn Evaluator + Send> {
        match self {
            Backend::Naive => Box::new(NaiveEval::new(params)),
            Backend::BitPacked => Box::new(BitPackedEval::new(params)),
            Backend::Indexed => Box::new(IndexedEval::new(params)),
        }
    }

    /// Stable lowercase name used by the CLI and bench reports.
    pub fn name(self) -> &'static str {
        match self {
            Backend::Naive => "naive",
            Backend::BitPacked => "bitpacked",
            Backend::Indexed => "indexed",
        }
    }

    /// Every backend, in ablation order (naive, bitpacked, indexed).
    pub const ALL: [Backend; 3] = [Backend::Naive, Backend::BitPacked, Backend::Indexed];
}

impl std::str::FromStr for Backend {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "naive" => Ok(Backend::Naive),
            "bitpacked" => Ok(Backend::BitPacked),
            "indexed" => Ok(Backend::Indexed),
            other => Err(format!("unknown backend '{other}' (naive|bitpacked|indexed)")),
        }
    }
}
