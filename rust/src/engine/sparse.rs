//! Sparse-delta inference: O(nnz) scoring for k-hot workloads.
//!
//! The dense fused walk ([`crate::engine::FusedIndex`]) enumerates every
//! FALSE non-empty literal of a sample — for a `[x, ¬x]` literal vector
//! that is always exactly `o` literals, no matter how sparse the input.
//! On bag-of-words workloads (IMDb BoW at 5k–20k features, ≥95% zeros)
//! almost the whole walk re-falsifies the same clauses it would falsify
//! for the all-zeros input. The sparse index precomputes that baseline
//! once and scores each sample as a *delta* from it:
//!
//! * `base_false[gid]` — how many of clause `gid`'s included literals
//!   are false at `x = 0` (= its included **positive** literals; every
//!   negated literal is true at zero).
//! * `base_score[c]` — class `c`'s vote sum over non-empty clauses with
//!   `base_false == 0`, i.e. the exact inference score of `x = 0`.
//!
//! Scoring a sample then iterates only its **set** features. Setting
//! feature `k` toggles one literal pair: positive literal `k` turns
//! true (un-falsifying the clauses on list `L_k`) and negated literal
//! `o + k` turns false (falsifying the clauses on `L_{o+k}`). A
//! per-clause falsification counter seeded lazily from `base_false`
//! (generation stamps — no O(clauses) clearing per sample) absorbs both
//! toggles; a clause's vote moves iff its counter crosses zero:
//!
//! ```text
//! score(x) = base_score[c]
//!          + Σ vote(g)  over touched g: base_false[g] > 0, count(g) == 0
//!          - Σ vote(g)  over touched g: base_false[g] == 0, count(g) > 0
//! ```
//!
//! Total cost is `Σ_{k set} |L_k| + |L_{o+k}|` — proportional to nnz,
//! not to `o`. Exact integer arithmetic throughout: scores are
//! bit-identical to the dense fused walk and to `reference_score`.
//!
//! Maintenance is the paper's O(1) insert/delete algebra on the same
//! [`ListStore`]/[`PositionStore`] pair, extended with the
//! baseline/delta bookkeeping: an include/exclude of a *positive*
//! literal moves `base_false`, and every flip re-evaluates the clause's
//! "fires at zero" predicate to keep `base_score` current — so the
//! index stays valid **during training**, exactly like the dense fused
//! index ([`FlipSink`] with global clause ids).

use crate::data::SparseSample;
use crate::engine::fused::Maintenance;
use crate::engine::shard::{score_batch_sharded, ShardScorer};
use crate::eval::traits::FlipSink;
use crate::index::liststore::ListStore;
use crate::index::position::PositionStore;
use crate::obs::ProbeDelta;
use crate::tm::bank::ClauseBank;
use crate::tm::classifier::MultiClassTM;
use crate::tm::params::TMParams;
use crate::util::simd::SimdLanes;
use crate::util::BitVec;

/// Which inference engine `Trainer::predict`-side serving uses for the
/// indexed backend.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum InferMode {
    /// Measure input density per call; sparse below
    /// [`SPARSE_DENSITY_THRESHOLD`], dense otherwise.
    #[default]
    Auto,
    /// Always the dense class-fused walk.
    Dense,
    /// Always the O(nnz) sparse-delta walk (inputs must be
    /// complement-structured `[x, ¬x]` literal vectors).
    Sparse,
}

impl InferMode {
    /// Stable lowercase name used by the CLI, model files, and `stats`.
    pub fn name(self) -> &'static str {
        match self {
            InferMode::Auto => "auto",
            InferMode::Dense => "dense",
            InferMode::Sparse => "sparse",
        }
    }
}

impl std::str::FromStr for InferMode {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "auto" => Ok(InferMode::Auto),
            "dense" => Ok(InferMode::Dense),
            "sparse" => Ok(InferMode::Sparse),
            other => Err(format!("unknown infer mode '{other}' (auto|dense|sparse)")),
        }
    }
}

/// Feature-density cutoff for [`InferMode::Auto`]: inputs with fewer
/// than this fraction of features set route to the sparse-delta walk.
///
/// The sparse walk touches the two inclusion lists of each *set*
/// feature (`2·d·o` rows) where the dense walk touches one list per
/// *false* literal (`o` rows for `[x, ¬x]` inputs), so under uniform
/// list lengths sparse wins below d = 0.5. Real BoW lists are skewed
/// toward frequent (often-set) tokens, which eats into that margin —
/// 0.2 keeps a comfortable buffer while still capturing every workload
/// the paper calls sparse (IMDb BoW sits at 0.02–0.05).
pub const SPARSE_DENSITY_THRESHOLD: f64 = 0.2;

/// Resolve [`InferMode::Auto`] against a probe of batch samples: sparse
/// iff every probed sample is a complement-structured `[x, ¬x]` literal
/// vector over `features` features and the probe's mean feature density
/// is below [`SPARSE_DENSITY_THRESHOLD`]. Forced modes pass through
/// unchanged, and an empty probe resolves dense.
///
/// At most 32 samples are probed, keeping selection O(1) per batch; the
/// complement proof per sample is O(o/64), negligible next to either
/// walk. Shared by [`crate::tm::trainer::Trainer`] and the serving
/// snapshot ([`crate::engine::snapshot::ModelSnapshot`]) so both pick
/// the same engine for the same inputs.
pub fn resolve_infer_mode<'a>(
    features: usize,
    mode: InferMode,
    probe: impl IntoIterator<Item = &'a BitVec>,
) -> InferMode {
    match mode {
        InferMode::Dense => InferMode::Dense,
        InferMode::Sparse => InferMode::Sparse,
        InferMode::Auto => {
            const PROBE: usize = 32;
            let mut n = 0usize;
            let mut total = 0.0;
            for literals in probe.into_iter().take(PROBE) {
                if features == 0
                    || literals.len() != 2 * features
                    || !literals.halves_complement()
                {
                    return InferMode::Dense;
                }
                total += literals.count_ones_prefix(features) as f64 / features as f64;
                n += 1;
            }
            if n > 0 && total / n as f64 < SPARSE_DENSITY_THRESHOLD {
                InferMode::Sparse
            } else {
                InferMode::Dense
            }
        }
    }
}

/// Per-global-clause constants read on the delta hot path.
#[derive(Clone, Copy, Debug)]
struct SparseMeta {
    vote: i32,
    class: u32,
}

/// The sparse-delta index: global-id inclusion lists (same CSR layout
/// as the dense fused index) plus the all-zeros baseline.
#[derive(Clone, Debug)]
pub struct SparseFusedIndex {
    classes: usize,
    clauses_per_class: usize,
    /// Raw feature count `o` (literal `k < o` is positive, `o + k`
    /// negated).
    features: usize,
    n_literals: usize,
    /// `L_k` rows over global clause ids.
    lists: ListStore,
    /// `M[gid][k]` — only in [`Maintenance::Maintained`] mode.
    pos: Option<PositionStore>,
    /// Per-global-clause vote + class.
    meta: Vec<SparseMeta>,
    /// Included-positive-literal count per clause = false-literal count
    /// at `x = 0`.
    base_false: Vec<u32>,
    /// Per-class exact inference score of the all-zeros input.
    base_score: Vec<i32>,
    /// Lane selector resolved from [`TMParams::simd`]: the wide setting
    /// walks each inclusion-list row in 4-gid quads and prefetches the
    /// next quad's scratch gather lines (see [`toggle_row`]).
    simd: SimdLanes,
}

/// Prefetch the cache line at `p` (no-op off x86_64).
#[inline(always)]
fn prefetch(p: *const u32) {
    #[cfg(target_arch = "x86_64")]
    unsafe {
        core::arch::x86_64::_mm_prefetch(p as *const i8, core::arch::x86_64::_MM_HINT_T0);
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = p;
}

/// One toggle: seed clause `gid`'s falsification counter from
/// `base_false` on first touch this evaluation (generation stamp), then
/// move it by `delta` (+1 falsify, -1 un-falsify).
#[inline(always)]
fn touch_gid(
    gid: u32,
    delta: i32,
    stamp: u32,
    base_false: &[u32],
    gen: &mut [u32],
    count: &mut [i32],
    touched: &mut Vec<u32>,
) {
    let g = gid as usize;
    if gen[g] != stamp {
        gen[g] = stamp;
        count[g] = base_false[g] as i32;
        touched.push(gid);
    }
    count[g] += delta;
}

/// Apply one inclusion-list row's toggles to the stamped counters.
///
/// The wide variant walks the row in 4-gid quads, issuing prefetches
/// for the *next* quad's `gen`/`count` gather lines while the current
/// quad resolves. The toggle loop is a dependent random-access
/// gather/scatter chain — unlike the dense walk's bitmap OR there is
/// no data-parallel algebra to vectorize, so the lanes here buy
/// latency hiding, not wider ALU work. The arithmetic and the touch
/// order are identical either way: counts, `touched`, probes, and
/// scores stay bit-exact with the scalar walk
/// (`rust/tests/simd_equiv.rs`).
#[inline(always)]
fn toggle_row(
    row: &[u32],
    delta: i32,
    stamp: u32,
    wide: bool,
    base_false: &[u32],
    gen: &mut [u32],
    count: &mut [i32],
    touched: &mut Vec<u32>,
) {
    const QUAD: usize = 4;
    let mut i = 0;
    if wide {
        while i + QUAD <= row.len() {
            if i + 2 * QUAD <= row.len() {
                for &gn in &row[i + QUAD..i + 2 * QUAD] {
                    let g = gn as usize;
                    prefetch(&gen[g] as *const u32);
                    prefetch(&count[g] as *const i32 as *const u32);
                }
            }
            for &gid in &row[i..i + QUAD] {
                touch_gid(gid, delta, stamp, base_false, gen, count, touched);
            }
            i += QUAD;
        }
    }
    for &gid in &row[i..] {
        touch_gid(gid, delta, stamp, base_false, gen, count, touched);
    }
}

impl SparseFusedIndex {
    /// Empty index for a fresh machine.
    pub fn new(params: &TMParams, maintenance: Maintenance) -> Self {
        let total = params.total_clauses();
        let n_lit = params.n_literals();
        SparseFusedIndex {
            classes: params.classes,
            clauses_per_class: params.clauses_per_class,
            features: params.features,
            n_literals: n_lit,
            lists: ListStore::auto(total, n_lit),
            pos: match maintenance {
                Maintenance::Maintained => Some(PositionStore::auto(total, n_lit)),
                Maintenance::Frozen => None,
            },
            meta: (0..total)
                .map(|g| SparseMeta {
                    vote: ClauseBank::polarity(g),
                    class: (g / params.clauses_per_class) as u32,
                })
                .collect(),
            base_false: vec![0; total],
            base_score: vec![0; params.classes],
            simd: params.simd.resolve(),
        }
    }

    /// Build from a trained machine.
    pub fn from_machine(tm: &MultiClassTM, maintenance: Maintenance) -> Self {
        let mut idx = SparseFusedIndex::new(&tm.params, maintenance);
        idx.rebuild(tm);
        idx
    }

    /// Rebuild all derived state from the machine's banks.
    pub fn rebuild(&mut self, tm: &MultiClassTM) {
        let params = &tm.params;
        assert_eq!(params.classes, self.classes);
        assert_eq!(params.clauses_per_class, self.clauses_per_class);
        let total = params.total_clauses();
        self.lists = ListStore::auto(total, self.n_literals);
        if self.pos.is_some() {
            self.pos = Some(PositionStore::auto(total, self.n_literals));
        }
        self.base_false = vec![0; total];
        self.base_score = vec![0; self.classes];
        for c in 0..self.classes {
            let bank = tm.bank(c);
            for j in 0..bank.clauses() {
                let gid = self.global_id(c, j);
                self.meta[gid as usize] = SparseMeta {
                    vote: bank.vote(j),
                    class: c as u32,
                };
                let mut positives = 0u32;
                for k in bank.included_literals(j) {
                    if k < self.features {
                        positives += 1;
                    }
                    let p = self.lists.push(k, gid);
                    if let Some(pos) = &mut self.pos {
                        pos.set(gid, k as u32, p);
                    }
                }
                self.base_false[gid as usize] = positives;
                if bank.count(j) > 0 && positives == 0 {
                    self.base_score[c] += bank.vote(j);
                }
            }
        }
    }

    /// Global clause id of `(class, local clause)`.
    #[inline]
    pub fn global_id(&self, class: usize, j: usize) -> u32 {
        (class * self.clauses_per_class + j) as u32
    }

    #[inline]
    /// Number of classes fused into this index.
    pub fn classes(&self) -> usize {
        self.classes
    }

    #[inline]
    /// Number of raw boolean features.
    pub fn features(&self) -> usize {
        self.features
    }

    #[inline]
    /// Number of literals (2 × features) per clause.
    pub fn n_literals(&self) -> usize {
        self.n_literals
    }

    #[inline]
    /// Total clauses across every class (the global-id space).
    pub fn total_clauses(&self) -> usize {
        self.classes * self.clauses_per_class
    }

    /// Per-class exact scores of the all-zeros input.
    pub fn base_score(&self) -> &[i32] {
        &self.base_score
    }

    /// True if the position matrix is kept for O(1) maintenance.
    pub fn is_maintained(&self) -> bool {
        self.pos.is_some()
    }

    /// Approximate resident bytes (capacity diagnostics).
    pub fn footprint_bytes(&self) -> usize {
        self.lists.footprint_bytes()
            + self.pos.as_ref().map_or(0, |p| p.footprint_bytes())
            + self.meta.len() * std::mem::size_of::<SparseMeta>()
            + self.base_false.len() * std::mem::size_of::<u32>()
    }

    fn pos_mut(&mut self) -> &mut PositionStore {
        self.pos.as_mut().expect(
            "frozen SparseFusedIndex cannot accept flips; build with Maintenance::Maintained",
        )
    }

    /// Does clause `gid` fire on the all-zeros input, given its current
    /// include-count?
    #[inline]
    fn fires_at_zero(&self, gid: u32, count: u32) -> bool {
        count > 0 && self.base_false[gid as usize] == 0
    }

    /// O(1) insertion (TA flipped exclude -> include), global clause id.
    pub fn insert(&mut self, gid: u32, k: u32, new_count: u32, weight: u32) {
        if let Some(p) = &self.pos {
            debug_assert!(p.get(gid, k).is_none(), "duplicate insert ({gid},{k})");
        }
        debug_assert_eq!(
            self.meta[gid as usize].vote,
            ClauseBank::polarity(gid as usize) * weight as i32,
            "meta vote out of sync with clause weight"
        );
        let p = self.lists.push(k as usize, gid);
        self.pos_mut().set(gid, k, p);
        let fired = self.fires_at_zero(gid, new_count - 1);
        if (k as usize) < self.features {
            self.base_false[gid as usize] += 1;
        }
        let fires = self.fires_at_zero(gid, new_count);
        self.apply_zero_transition(gid, fired, fires);
    }

    /// O(1) deletion by swap-with-last, global clause id.
    pub fn delete(&mut self, gid: u32, k: u32, new_count: u32, weight: u32) {
        let p = self
            .pos_mut()
            .remove(gid, k)
            .expect("delete of unindexed (clause, literal)");
        if let Some(moved) = self.lists.swap_remove(k as usize, p) {
            self.pos_mut().set(moved, k, p);
        }
        debug_assert_eq!(
            self.meta[gid as usize].vote,
            ClauseBank::polarity(gid as usize) * weight as i32,
            "meta vote out of sync with clause weight"
        );
        let fired = self.fires_at_zero(gid, new_count + 1);
        if (k as usize) < self.features {
            self.base_false[gid as usize] -= 1;
        }
        let fires = self.fires_at_zero(gid, new_count);
        self.apply_zero_transition(gid, fired, fires);
    }

    #[inline]
    fn apply_zero_transition(&mut self, gid: u32, fired: bool, fires: bool) {
        if fired != fires {
            let m = self.meta[gid as usize];
            let d = if fires { m.vote } else { -m.vote };
            self.base_score[m.class as usize] += d;
        }
    }

    /// Weight change of global clause `gid` (weighted TMs).
    pub fn weight_changed(&mut self, gid: u32, delta: i32, nonempty: bool) {
        let d = ClauseBank::polarity(gid as usize) * delta;
        let m = &mut self.meta[gid as usize];
        m.vote += d;
        let class = m.class as usize;
        if nonempty && self.base_false[gid as usize] == 0 {
            self.base_score[class] += d;
        }
    }

    /// Fresh scratch sized for this index.
    pub fn make_scratch(&self) -> SparseScratch {
        SparseScratch::new(self.total_clauses())
    }

    /// Score one k-hot sample (its sorted set-feature ids) against
    /// **all classes** in O(nnz), writing class `c`'s inference score
    /// to `out[c]`.
    ///
    /// Bit-identical to [`crate::engine::FusedIndex::score_into`] on the
    /// materialized `[x, ¬x]` literal vector: both compute the same
    /// exact integer score, one from the all-true baseline minus
    /// falsified votes, this one from the all-zeros baseline plus the
    /// delta of clauses whose falsification count crosses zero.
    pub fn score_sparse_into(&self, scratch: &mut SparseScratch, set: &[u32], out: &mut [i32]) {
        assert_eq!(out.len(), self.classes);
        debug_assert_eq!(scratch.count.len(), self.total_clauses());
        debug_assert!(set.iter().all(|&k| (k as usize) < self.features));
        out.copy_from_slice(&self.base_score);
        let SparseScratch {
            gen,
            cur_gen,
            count,
            touched,
            probes,
            ..
        } = scratch;
        *cur_gen = cur_gen.wrapping_add(1);
        if *cur_gen == 0 {
            // wrapped: stamps from 4 billion evals ago could collide
            gen.fill(0);
            *cur_gen = 1;
        }
        let stamp = *cur_gen;
        touched.clear();
        let o = self.features;
        let wide = self.simd == SimdLanes::Wide;
        let mut toggles: u64 = 0;
        const LOOKAHEAD: usize = 4;
        for (i, &k) in set.iter().enumerate() {
            if let Some(&kn) = set.get(i + LOOKAHEAD) {
                prefetch(self.lists.row_ptr(kn as usize));
                prefetch(self.lists.row_ptr(o + kn as usize));
            }
            // negated literal o+k turns false: falsify
            let row = self.lists.row(o + k as usize);
            toggles += row.len() as u64;
            toggle_row(row, 1, stamp, wide, &self.base_false, gen, count, touched);
            // positive literal k turns true: un-falsify
            let row = self.lists.row(k as usize);
            toggles += row.len() as u64;
            toggle_row(row, -1, stamp, wide, &self.base_false, gen, count, touched);
        }
        for &gid in touched.iter() {
            let g = gid as usize;
            let base_falsified = self.base_false[g] > 0;
            let now_falsified = count[g] > 0;
            if base_falsified != now_falsified {
                let m = self.meta[g];
                if now_falsified {
                    // counted in base_score, but this sample kills it
                    out[m.class as usize] -= m.vote;
                } else {
                    // absent from base_score, but this sample revives it
                    out[m.class as usize] += m.vote;
                }
            }
        }
        // Index-efficiency probes: plain adds on a per-sample scratch —
        // no atomics on the hot path; the batch worker flushes them.
        // "Falsified" here means clauses the delta walk actually
        // touched; everything untouched rode the all-zeros baseline.
        probes.sparse_samples += 1;
        probes.features_walked += set.len() as u64;
        probes.sparse_toggles += toggles;
        probes.clauses_falsified += touched.len() as u64;
        probes.clauses_skipped += self.meta.len() as u64 - touched.len() as u64;
    }

    /// Score a dense `[x, ¬x]` literal vector by extracting its set
    /// features into scratch first. The vector must be
    /// complement-structured (every [`crate::data::Dataset`] sample is).
    pub fn score_literals_into(
        &self,
        scratch: &mut SparseScratch,
        literals: &BitVec,
        out: &mut [i32],
    ) {
        assert_eq!(literals.len(), self.n_literals);
        debug_assert!(
            (0..self.features).all(|k| literals.get(k) != literals.get(self.features + k)),
            "sparse walk requires complement-structured [x, ¬x] literals"
        );
        let mut feats = std::mem::take(&mut scratch.feats);
        feats.clear();
        feats.extend(
            literals
                .iter_ones()
                .take_while(|&k| k < self.features)
                .map(|k| k as u32),
        );
        self.score_sparse_into(scratch, &feats, out);
        scratch.feats = feats;
    }

    /// Full structural + baseline invariant check against the machine
    /// (tests) — the sparse mirror of `ClassIndex::check_invariants`.
    #[doc(hidden)]
    pub fn check_invariants(&self, tm: &MultiClassTM) -> Result<(), String> {
        let n = self.clauses_per_class;
        // 1. every list entry is a real inclusion (and positioned, if
        //    maintained)
        for k in 0..self.n_literals {
            for (p, &gid) in self.lists.row(k).iter().enumerate() {
                let (c, j) = (gid as usize / n, gid as usize % n);
                if !tm.bank(c).include(j, k) {
                    return Err(format!("list {k} holds non-included clause {gid}"));
                }
                if let Some(pos) = &self.pos {
                    if pos.get(gid, k as u32) != Some(p as u32) {
                        return Err(format!("M[{gid}][{k}] != {p}"));
                    }
                }
            }
        }
        // 2. every inclusion is listed; base_false, votes and the
        //    baseline scores agree with the banks
        let mut listed_total = 0usize;
        for c in 0..self.classes {
            let bank = tm.bank(c);
            let mut want_base = 0i32;
            for j in 0..n {
                let gid = self.global_id(c, j);
                if self.meta[gid as usize].vote != bank.vote(j) {
                    return Err(format!("meta vote of {gid} != bank vote"));
                }
                if self.meta[gid as usize].class != c as u32 {
                    return Err(format!("meta class of {gid} != {c}"));
                }
                let mut positives = 0u32;
                for k in bank.included_literals(j) {
                    if k < self.features {
                        positives += 1;
                    }
                    if !self.lists.row(k).contains(&gid) {
                        return Err(format!("missing list entry ({gid},{k})"));
                    }
                }
                if self.base_false[gid as usize] != positives {
                    return Err(format!(
                        "base_false[{gid}] {} != included positives {}",
                        self.base_false[gid as usize], positives
                    ));
                }
                if bank.count(j) > 0 && positives == 0 {
                    want_base += bank.vote(j);
                }
                listed_total += bank.count(j) as usize;
            }
            if self.base_score[c] != want_base {
                return Err(format!(
                    "base_score[{c}] {} != recomputed {}",
                    self.base_score[c], want_base
                ));
            }
        }
        let listed: usize = self.lists.lens().iter().map(|&l| l as usize).sum();
        if listed != listed_total {
            return Err(format!("listed {listed} != included {listed_total}"));
        }
        Ok(())
    }
}

impl FlipSink for SparseFusedIndex {
    /// `j` is a **global** clause id (see [`SparseFusedIndex::global_id`]).
    #[inline]
    fn on_include(&mut self, j: u32, k: u32, new_count: u32, weight: u32) {
        self.insert(j, k, new_count, weight);
    }
    #[inline]
    fn on_exclude(&mut self, j: u32, k: u32, new_count: u32, weight: u32) {
        self.delete(j, k, new_count, weight);
    }
    #[inline]
    fn on_weight(&mut self, j: u32, delta: i32, nonempty: bool) {
        self.weight_changed(j, delta, nonempty);
    }
}

impl ShardScorer<BitVec> for SparseFusedIndex {
    type Scratch = SparseScratch;

    fn classes(&self) -> usize {
        SparseFusedIndex::classes(self)
    }

    #[inline]
    fn score_one(&self, scratch: &mut SparseScratch, literals: &BitVec, out: &mut [i32]) {
        self.score_literals_into(scratch, literals, out);
    }
}

impl ShardScorer<SparseSample> for SparseFusedIndex {
    type Scratch = SparseScratch;

    fn classes(&self) -> usize {
        SparseFusedIndex::classes(self)
    }

    #[inline]
    fn score_one(&self, scratch: &mut SparseScratch, sample: &SparseSample, out: &mut [i32]) {
        debug_assert_eq!(sample.features(), self.features);
        self.score_sparse_into(scratch, sample.ones(), out);
    }
}

/// Mutable per-evaluation state of the sparse walk, separated from the
/// read-only [`SparseFusedIndex`] so batch sharding hands one scratch
/// to each worker while all workers share the index.
///
/// `count` holds each touched clause's current falsification count,
/// seeded from `base_false` the first time the clause is touched in an
/// evaluation — the generation-stamp trick avoids clearing a
/// `total_clauses`-sized array per sample.
#[derive(Clone, Debug)]
pub struct SparseScratch {
    gen: Vec<u32>,
    cur_gen: u32,
    count: Vec<i32>,
    /// Clauses touched this evaluation (the only ones whose vote can
    /// move off baseline).
    touched: Vec<u32>,
    /// Set-feature extraction buffer for dense-literal inputs.
    feats: Vec<u32>,
    /// Accumulated index-efficiency probe counters (plain adds; drained
    /// with [`SparseScratch::take_probes`]).
    probes: ProbeDelta,
}

impl SparseScratch {
    /// Scratch sized for an index of `total_clauses` global ids.
    pub fn new(total_clauses: usize) -> Self {
        SparseScratch {
            gen: vec![0; total_clauses],
            cur_gen: 0,
            count: vec![0; total_clauses],
            touched: Vec::new(),
            feats: Vec::new(),
            probes: ProbeDelta::default(),
        }
    }

    /// Resize for a rebuilt index (stamps are invalidated).
    pub fn reset(&mut self, total_clauses: usize) {
        self.gen.clear();
        self.gen.resize(total_clauses, 0);
        self.count.clear();
        self.count.resize(total_clauses, 0);
        self.cur_gen = 0;
        self.touched.clear();
        self.feats.clear();
        self.probes = ProbeDelta::default();
    }

    /// Drain the probe counters accumulated since the last call.
    pub fn take_probes(&mut self) -> ProbeDelta {
        self.probes.take()
    }

    #[doc(hidden)]
    pub fn force_generation(&mut self, g: u32) {
        self.cur_gen = g;
    }
}

/// The sparse batch inference engine: sparse-delta index + pooled
/// scratches, the O(nnz) twin of [`crate::engine::FusedEngine`].
#[derive(Clone, Debug)]
pub struct SparseEngine {
    index: SparseFusedIndex,
    /// One scratch per potential worker; `scratches[0]` doubles as the
    /// serial/single-sample scratch.
    scratches: Vec<SparseScratch>,
}

impl SparseEngine {
    /// Snapshot a machine for serving with `threads` workers
    /// (1 = serial). The index is frozen — rebuild after training.
    pub fn from_machine(tm: &MultiClassTM, threads: usize) -> Self {
        Self::with_maintenance(tm, threads, Maintenance::Frozen)
    }

    /// Build with an explicit maintenance mode
    /// ([`Maintenance::Maintained`] keeps O(1) flip support).
    pub fn with_maintenance(tm: &MultiClassTM, threads: usize, maintenance: Maintenance) -> Self {
        let index = SparseFusedIndex::from_machine(tm, maintenance);
        let scratches = (0..threads.max(1)).map(|_| index.make_scratch()).collect();
        SparseEngine { index, scratches }
    }

    /// Wrap an existing index (tests, incremental maintenance).
    pub fn from_index(index: SparseFusedIndex, threads: usize) -> Self {
        let scratches = (0..threads.max(1)).map(|_| index.make_scratch()).collect();
        SparseEngine { index, scratches }
    }

    /// Refresh the index from the machine's current banks (after
    /// training steps) without reallocating the scratch pool.
    pub fn rebuild(&mut self, tm: &MultiClassTM) {
        self.index.rebuild(tm);
        let total = self.index.total_clauses();
        for s in &mut self.scratches {
            s.reset(total);
        }
    }

    /// The underlying sparse index.
    pub fn index(&self) -> &SparseFusedIndex {
        &self.index
    }

    /// Mutable index access (flip maintenance in `Maintained` mode).
    pub fn index_mut(&mut self) -> &mut SparseFusedIndex {
        &mut self.index
    }

    /// Configured worker count.
    pub fn threads(&self) -> usize {
        self.scratches.len()
    }

    /// Change the worker count (resizes the scratch pool).
    pub fn set_threads(&mut self, threads: usize) {
        let threads = threads.max(1);
        let total = self.index.total_clauses();
        self.scratches
            .resize_with(threads, || SparseScratch::new(total));
    }

    fn batch_workers(&self, batch_len: usize) -> usize {
        let threads = self.scratches.len();
        if threads > 1 && batch_len >= crate::engine::batch::MIN_SAMPLES_PER_WORKER * threads {
            threads
        } else {
            1
        }
    }

    /// Score one k-hot sample natively (no densification).
    pub fn score_sparse_into(&mut self, sample: &SparseSample, out: &mut [i32]) {
        debug_assert_eq!(sample.features(), self.index.features());
        self.index
            .score_sparse_into(&mut self.scratches[0], sample.ones(), out);
    }

    /// Score a k-hot batch natively into the row-major matrix
    /// `out[i * classes + c]`, sharding across the scratch pool.
    pub fn score_sparse_batch_into(&mut self, batch: &[SparseSample], out: &mut [i32]) {
        let workers = self.batch_workers(batch.len());
        score_batch_sharded(&self.index, &mut self.scratches[..workers], batch, out);
    }
}

impl crate::engine::batch::BatchScorer for SparseEngine {
    fn classes(&self) -> usize {
        self.index.classes()
    }

    fn n_literals(&self) -> usize {
        self.index.n_literals()
    }

    fn scores_into(&mut self, literals: &BitVec, out: &mut [i32]) {
        self.index
            .score_literals_into(&mut self.scratches[0], literals, out);
    }

    fn score_batch_into(&mut self, batch: &[BitVec], out: &mut [i32]) {
        let workers = self.batch_workers(batch.len());
        score_batch_sharded(&self.index, &mut self.scratches[..workers], batch, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::batch::BatchScorer;
    use crate::engine::fused::FusedIndex;
    use crate::eval::traits::reference_score;
    use crate::util::Rng;

    fn random_machine(
        rng: &mut Rng,
        classes: usize,
        clauses: usize,
        features: usize,
    ) -> MultiClassTM {
        let mut tm = MultiClassTM::new(TMParams::new(classes, clauses, features));
        let n_lit = 2 * features;
        for c in 0..classes {
            let bank = tm.bank_mut(c);
            for j in 0..clauses {
                for k in 0..n_lit {
                    if rng.bern(0.15) {
                        bank.set_state(j, k, (rng.below(11) as i8) - 5);
                    }
                }
            }
        }
        tm
    }

    fn random_khot(rng: &mut Rng, features: usize, density: f64) -> SparseSample {
        let set: Vec<u32> = (0..features as u32).filter(|_| rng.bern(density)).collect();
        SparseSample::new(features, set)
    }

    #[test]
    fn sparse_scores_match_reference_per_class() {
        let mut rng = Rng::new(141);
        for trial in 0..40 {
            let tm = random_machine(&mut rng, 3, 8, 15);
            let idx = SparseFusedIndex::from_machine(&tm, Maintenance::Frozen);
            let mut scratch = idx.make_scratch();
            let density = rng.unit_f64();
            let sample = random_khot(&mut rng, 15, density);
            let lits = sample.to_literals();
            let mut out = vec![0i32; 3];
            idx.score_sparse_into(&mut scratch, sample.ones(), &mut out);
            for c in 0..3 {
                assert_eq!(
                    out[c],
                    reference_score(tm.bank(c), &lits, false),
                    "class {c} trial {trial}"
                );
            }
        }
    }

    #[test]
    fn zero_input_scores_base_score() {
        let mut rng = Rng::new(142);
        let tm = random_machine(&mut rng, 4, 10, 20);
        let idx = SparseFusedIndex::from_machine(&tm, Maintenance::Frozen);
        let mut scratch = idx.make_scratch();
        let mut out = vec![0i32; 4];
        idx.score_sparse_into(&mut scratch, &[], &mut out);
        assert_eq!(out, idx.base_score());
        let zero = SparseSample::new(20, vec![]).to_literals();
        for c in 0..4 {
            assert_eq!(out[c], reference_score(tm.bank(c), &zero, false));
        }
    }

    #[test]
    fn scratch_reuse_is_clean_across_samples() {
        let mut rng = Rng::new(143);
        let tm = random_machine(&mut rng, 4, 10, 20);
        let idx = SparseFusedIndex::from_machine(&tm, Maintenance::Frozen);
        let mut scratch = idx.make_scratch();
        let mut out = vec![0i32; 4];
        for _ in 0..50 {
            let sample = random_khot(&mut rng, 20, 0.3);
            idx.score_sparse_into(&mut scratch, sample.ones(), &mut out);
            let lits = sample.to_literals();
            for c in 0..4 {
                assert_eq!(out[c], reference_score(tm.bank(c), &lits, false));
            }
        }
    }

    #[test]
    fn generation_wraparound_is_safe() {
        let mut rng = Rng::new(144);
        let tm = random_machine(&mut rng, 2, 6, 12);
        let idx = SparseFusedIndex::from_machine(&tm, Maintenance::Frozen);
        let mut scratch = idx.make_scratch();
        scratch.force_generation(u32::MAX - 2);
        let sample = random_khot(&mut rng, 12, 0.4);
        let lits = sample.to_literals();
        let want: Vec<i32> = (0..2)
            .map(|c| reference_score(tm.bank(c), &lits, false))
            .collect();
        let mut out = vec![0i32; 2];
        for _ in 0..6 {
            idx.score_sparse_into(&mut scratch, sample.ones(), &mut out);
            assert_eq!(out, want);
        }
    }

    #[test]
    fn dense_literal_entry_point_matches_fused() {
        let mut rng = Rng::new(145);
        let tm = random_machine(&mut rng, 3, 8, 25);
        let sparse = SparseFusedIndex::from_machine(&tm, Maintenance::Frozen);
        let dense = FusedIndex::from_machine(&tm, Maintenance::Frozen);
        let mut ss = sparse.make_scratch();
        let mut ds = dense.make_scratch();
        for _ in 0..30 {
            let lits = random_khot(&mut rng, 25, 0.2).to_literals();
            let mut a = vec![0i32; 3];
            let mut b = vec![0i32; 3];
            sparse.score_literals_into(&mut ss, &lits, &mut a);
            dense.score_into(&mut ds, &lits, &mut b);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn maintained_index_tracks_flip_storm() {
        use crate::tm::bank::Flip;
        let mut rng = Rng::new(146);
        let classes = 3;
        let clauses = 8;
        let n_lit = 24;
        let mut tm = random_machine(&mut rng, classes, clauses, n_lit / 2);
        let mut idx = SparseFusedIndex::from_machine(&tm, Maintenance::Maintained);
        for _ in 0..8000 {
            let c = rng.below(classes as u32) as usize;
            let j = rng.below(clauses as u32) as usize;
            let k = rng.below(n_lit as u32) as usize;
            let gid = idx.global_id(c, j);
            let bank = tm.bank_mut(c);
            if rng.bern(0.5) {
                if bank.bump_up(j, k) == Flip::Included {
                    let (count, weight) = (bank.count(j), bank.weight(j));
                    idx.on_include(gid, k as u32, count, weight);
                }
            } else if bank.bump_down(j, k) == Flip::Excluded {
                let (count, weight) = (bank.count(j), bank.weight(j));
                idx.on_exclude(gid, k as u32, count, weight);
            }
        }
        idx.check_invariants(&tm).unwrap();
        let mut scratch = idx.make_scratch();
        let mut out = vec![0i32; classes];
        let sample = random_khot(&mut rng, n_lit / 2, 0.4);
        let lits = sample.to_literals();
        idx.score_sparse_into(&mut scratch, sample.ones(), &mut out);
        for c in 0..classes {
            assert_eq!(out[c], reference_score(tm.bank(c), &lits, false));
        }
    }

    #[test]
    fn weight_changes_flow_into_base_score() {
        let mut tm = MultiClassTM::new(TMParams::new(2, 4, 3).with_weighted(true));
        // class 1, clause 2 (+ polarity): include negated literal ¬x0
        // (true at zero), weight 3 -> fires at the all-zeros baseline
        tm.bank_mut(1).set_state(2, 3, 0);
        tm.bank_mut(1).set_weight(2, 3);
        let mut idx = SparseFusedIndex::from_machine(&tm, Maintenance::Maintained);
        idx.check_invariants(&tm).unwrap();
        assert_eq!(idx.base_score()[1], 3);
        // +2 weight through the sink
        tm.bank_mut(1).set_weight(2, 5);
        let gid = idx.global_id(1, 2);
        idx.on_weight(gid, 2, true);
        idx.check_invariants(&tm).unwrap();
        assert_eq!(idx.base_score()[1], 5);
        let mut scratch = idx.make_scratch();
        let mut out = vec![0i32; 2];
        idx.score_sparse_into(&mut scratch, &[], &mut out);
        assert_eq!(out, vec![0, 5]);
        // setting x0 falsifies it
        idx.score_sparse_into(&mut scratch, &[0], &mut out);
        assert_eq!(out, vec![0, 0]);
    }

    #[test]
    #[should_panic(expected = "frozen SparseFusedIndex")]
    fn frozen_index_rejects_flips() {
        let tm = MultiClassTM::new(TMParams::new(2, 4, 3));
        let mut idx = SparseFusedIndex::from_machine(&tm, Maintenance::Frozen);
        idx.on_include(0, 0, 1, 1);
    }

    #[test]
    fn engine_batch_paths_agree() {
        let mut rng = Rng::new(147);
        let tm = random_machine(&mut rng, 4, 12, 30);
        let samples: Vec<SparseSample> =
            (0..40).map(|_| random_khot(&mut rng, 30, 0.1)).collect();
        let lits: Vec<BitVec> = samples.iter().map(SparseSample::to_literals).collect();
        let mut eng = SparseEngine::from_machine(&tm, 3);
        let mut via_dense = vec![0i32; 40 * 4];
        eng.score_batch_into(&lits, &mut via_dense);
        let mut via_sparse = vec![0i32; 40 * 4];
        eng.score_sparse_batch_into(&samples, &mut via_sparse);
        assert_eq!(via_dense, via_sparse);
        for (i, l) in lits.iter().enumerate() {
            for c in 0..4 {
                assert_eq!(
                    via_sparse[i * 4 + c],
                    reference_score(tm.bank(c), l, false),
                    "sample {i} class {c}"
                );
            }
        }
    }

    #[test]
    fn engine_rebuild_tracks_machine_changes() {
        let mut rng = Rng::new(148);
        let mut tm = random_machine(&mut rng, 3, 8, 12);
        let mut eng = SparseEngine::from_machine(&tm, 2);
        let sample = random_khot(&mut rng, 12, 0.25);
        let mut out = vec![0i32; 3];
        eng.score_sparse_into(&sample, &mut out);
        tm.bank_mut(1).set_state(0, 5, 1);
        tm.bank_mut(2).set_state(3, 2, 1);
        eng.rebuild(&tm);
        eng.index().check_invariants(&tm).unwrap();
        eng.score_sparse_into(&sample, &mut out);
        let lits = sample.to_literals();
        for c in 0..3 {
            assert_eq!(out[c], reference_score(tm.bank(c), &lits, false));
        }
    }

    #[test]
    fn wide_toggle_walk_matches_scalar_bit_exactly() {
        use crate::util::simd::SimdMode;
        let mut rng = Rng::new(149);
        let mut tm = random_machine(&mut rng, 3, 10, 40);
        for (mode, lanes) in [
            (SimdMode::Scalar, SimdLanes::Scalar),
            (SimdMode::Wide, SimdLanes::Wide),
        ] {
            tm.set_simd(mode);
            let idx = SparseFusedIndex::from_machine(&tm, Maintenance::Frozen);
            assert_eq!(idx.simd, lanes);
        }
        tm.set_simd(SimdMode::Scalar);
        let scalar = SparseFusedIndex::from_machine(&tm, Maintenance::Frozen);
        tm.set_simd(SimdMode::Wide);
        let wide = SparseFusedIndex::from_machine(&tm, Maintenance::Frozen);
        let mut ss = scalar.make_scratch();
        let mut ws = wide.make_scratch();
        for _ in 0..60 {
            let sample = random_khot(&mut rng, 40, rng.unit_f64());
            let mut a = vec![0i32; 3];
            let mut b = vec![0i32; 3];
            scalar.score_sparse_into(&mut ss, sample.ones(), &mut a);
            wide.score_sparse_into(&mut ws, sample.ones(), &mut b);
            assert_eq!(a, b);
            let lits = sample.to_literals();
            for c in 0..3 {
                assert_eq!(a[c], reference_score(tm.bank(c), &lits, false));
            }
        }
        // probes (toggle/touch counts) are part of the contract too
        assert_eq!(ss.take_probes(), ws.take_probes());
    }

    #[test]
    fn infer_mode_parses() {
        assert_eq!("auto".parse::<InferMode>().unwrap(), InferMode::Auto);
        assert_eq!("dense".parse::<InferMode>().unwrap(), InferMode::Dense);
        assert_eq!("sparse".parse::<InferMode>().unwrap(), InferMode::Sparse);
        assert!("fast".parse::<InferMode>().is_err());
        assert_eq!(InferMode::Sparse.name(), "sparse");
    }
}
