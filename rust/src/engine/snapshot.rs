//! Immutable published inference snapshots — the unit of hot swap.
//!
//! A [`ModelSnapshot`] freezes a trained machine together with both
//! inference engines' read-only indexes: the dense class-fused
//! [`FusedIndex`] and the O(nnz) [`SparseFusedIndex`], each built in
//! [`Maintenance::Frozen`] mode (no position matrix — inference never
//! deletes, and the matrix is the index's dominant memory cost). The
//! snapshot owns no mutable state at all: scoring threads each hold a
//! private [`SnapshotScratch`] and share the snapshot behind an `Arc`,
//! so the serving coordinator can atomically replace the `Arc` under
//! live traffic ([`crate::coordinator::Coordinator::swap`]) and every
//! request is scored by exactly one published version — never a torn
//! mixture of two.
//!
//! This is the paper's train-while-serving story (arXiv 2004.03188 §3:
//! constant-time index updates keep learning cheap next to serving):
//! a trainer keeps learning, periodically calls
//! [`crate::tm::trainer::Trainer::publish`], and pushes the resulting
//! snapshot into the coordinator without restarting it. The online
//! learner ([`crate::coordinator::online`]) automates that loop inside
//! the server: `feedback` traffic mutates its live maintained-index
//! trainer while readers keep scoring the last published snapshot,
//! and each cadence publish is an ordinary atomic swap of one of
//! these frozen values.

use crate::engine::fused::{FusedIndex, FusedScratch, Maintenance};
use crate::engine::sparse::{resolve_infer_mode, InferMode, SparseFusedIndex, SparseScratch};
use crate::tm::classifier::MultiClassTM;
use crate::util::BitVec;

/// A frozen, versioned, shareable serving model: machine + both
/// inference indexes. Construct via [`ModelSnapshot::new`] (or
/// [`crate::tm::trainer::Trainer::publish`]) and wrap in an `Arc`.
#[derive(Clone, Debug)]
pub struct ModelSnapshot {
    version: u64,
    tm: MultiClassTM,
    /// `None` iff the mode is forced [`InferMode::Sparse`] (the dense
    /// walk is unreachable, so its index is never built).
    fused: Option<FusedIndex>,
    /// `None` iff the mode is forced [`InferMode::Dense`].
    sparse: Option<SparseFusedIndex>,
    infer_mode: InferMode,
}

impl ModelSnapshot {
    /// Freeze `tm` for serving as `version` with [`InferMode::Auto`]
    /// selection (both engines built). Versions are chosen by the
    /// publisher (monotonically increasing per route) and surfaced by
    /// the coordinator's `stats` verb.
    pub fn new(tm: MultiClassTM, version: u64) -> Self {
        Self::with_mode(tm, version, InferMode::Auto)
    }

    /// Freeze `tm` with an explicit engine policy. A forced mode only
    /// builds the index it can reach — republish-heavy forced-mode
    /// routes (`tmi serve --watch --infer dense`) skip the other
    /// engine's build cost and memory entirely.
    pub fn with_mode(tm: MultiClassTM, version: u64, mode: InferMode) -> Self {
        let fused = (mode != InferMode::Sparse)
            .then(|| FusedIndex::from_machine(&tm, Maintenance::Frozen));
        let sparse = (mode != InferMode::Dense)
            .then(|| SparseFusedIndex::from_machine(&tm, Maintenance::Frozen));
        ModelSnapshot {
            version,
            tm,
            fused,
            sparse,
            infer_mode: mode,
        }
    }

    /// Dense/sparse engine selection policy (default [`InferMode::Auto`]).
    /// Builds any index the new mode can reach that is missing, and
    /// drops the one it cannot.
    pub fn with_infer_mode(mut self, mode: InferMode) -> Self {
        self.infer_mode = mode;
        if mode != InferMode::Sparse && self.fused.is_none() {
            self.fused = Some(FusedIndex::from_machine(&self.tm, Maintenance::Frozen));
        }
        if mode != InferMode::Dense && self.sparse.is_none() {
            self.sparse = Some(SparseFusedIndex::from_machine(&self.tm, Maintenance::Frozen));
        }
        match mode {
            InferMode::Sparse => self.fused = None,
            InferMode::Dense => self.sparse = None,
            InferMode::Auto => {}
        }
        self
    }

    /// Publisher-assigned version of this frozen snapshot.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Number of classes.
    pub fn classes(&self) -> usize {
        self.tm.classes()
    }

    /// Number of literals (2 × features) per clause.
    pub fn n_literals(&self) -> usize {
        self.tm.params.n_literals()
    }

    /// Number of raw boolean features.
    pub fn features(&self) -> usize {
        self.tm.params.features
    }

    /// The engine-selection policy baked into the snapshot.
    pub fn infer_mode(&self) -> InferMode {
        self.infer_mode
    }

    /// The frozen machine (weights/states are immutable snapshots).
    pub fn tm(&self) -> &MultiClassTM {
        &self.tm
    }

    /// Content digest of the frozen machine (CRC-32 of its serialized
    /// v3 image, see [`crate::tm::io::model_digest`]). Two snapshots
    /// share a digest iff they would score bit-identically — the
    /// crash-recovery tests' equality witness, and cheap enough to
    /// compute per publish.
    pub fn state_digest(&self) -> u32 {
        crate::tm::io::model_digest(&self.tm)
    }

    /// Fresh per-thread scratch sized for this snapshot's machine
    /// (both engines share the clause-count dimension).
    pub fn make_scratch(&self) -> SnapshotScratch {
        let total = self.tm.params.total_clauses();
        SnapshotScratch {
            fused: FusedScratch::new(total),
            sparse: SparseScratch::new(total),
        }
    }

    /// Resolve the engine for a probe of samples (see
    /// [`resolve_infer_mode`]).
    pub fn resolve_mode<'a>(&self, probe: impl IntoIterator<Item = &'a BitVec>) -> InferMode {
        resolve_infer_mode(self.tm.params.features, self.infer_mode, probe)
    }

    /// Score one sample against all classes with an already-resolved
    /// engine (`out.len() == classes`). Bit-identical to
    /// [`crate::tm::trainer::Trainer::scores_into`] for the indexed
    /// backend.
    pub fn score_into(
        &self,
        scratch: &mut SnapshotScratch,
        mode: InferMode,
        literals: &BitVec,
        out: &mut [i32],
    ) {
        match mode {
            InferMode::Sparse => self
                .sparse
                .as_ref()
                .expect("sparse walk requested from a dense-forced snapshot")
                .score_literals_into(&mut scratch.sparse, literals, out),
            InferMode::Dense | InferMode::Auto => self
                .fused
                .as_ref()
                .expect("dense walk requested from a sparse-forced snapshot")
                .score_into(&mut scratch.fused, literals, out),
        }
    }

    /// Convenience: resolve + score one sample.
    pub fn scores_into(&self, scratch: &mut SnapshotScratch, literals: &BitVec, out: &mut [i32]) {
        let mode = self.resolve_mode(std::iter::once(literals));
        self.score_into(scratch, mode, literals, out);
    }
}

/// Per-thread mutable evaluation state for scoring against a shared
/// [`ModelSnapshot`]: one scratch per engine, both generation-stamped
/// so reuse across samples needs no clearing.
#[derive(Clone, Debug)]
pub struct SnapshotScratch {
    fused: FusedScratch,
    sparse: SparseScratch,
}

impl SnapshotScratch {
    /// Drain both engines' index-efficiency probe counters into one
    /// merged delta (see [`crate::obs::ProbeDelta`]). The serving
    /// worker calls this once per batch and folds the result into the
    /// route's [`crate::coordinator::Metrics`].
    pub fn take_probes(&mut self) -> crate::obs::ProbeDelta {
        let mut delta = self.fused.take_probes();
        delta.merge(&self.sparse.take_probes());
        delta
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::Backend;
    use crate::tm::params::TMParams;
    use crate::tm::trainer::Trainer;
    use crate::util::Rng;

    fn trained(seed: u64) -> Trainer {
        let params = TMParams::new(3, 12, 16).with_seed(seed);
        let mut tr = Trainer::new(params, Backend::Indexed);
        let mut rng = Rng::new(seed ^ 0xabc);
        let samples: Vec<(BitVec, usize)> = (0..150)
            .map(|_| {
                let y = rng.below(3) as usize;
                let bits: Vec<bool> =
                    (0..16).map(|k| k % 3 == y || rng.bern(0.2)).collect();
                let mut lits = bits.clone();
                lits.extend(bits.iter().map(|b| !b));
                (BitVec::from_bools(&lits), y)
            })
            .collect();
        for _ in 0..3 {
            tr.train_epoch(samples.iter().map(|(l, y)| (l, *y)));
        }
        tr
    }

    fn complement_lits(rng: &mut Rng, features: usize, density: f64) -> BitVec {
        let bits: Vec<bool> = (0..features).map(|_| rng.bern(density)).collect();
        let mut lits = bits.clone();
        lits.extend(bits.iter().map(|b| !b));
        BitVec::from_bools(&lits)
    }

    #[test]
    fn snapshot_scores_match_trainer_on_every_mode() {
        let mut tr = trained(5);
        let snap = tr.publish();
        assert_eq!(snap.version(), 1);
        assert_eq!(snap.classes(), 3);
        assert_eq!(snap.n_literals(), 32);
        let mut scratch = snap.make_scratch();
        let mut rng = Rng::new(9);
        for trial in 0..60 {
            // alternate dense-ish and sparse-ish complement inputs
            let density = if trial % 2 == 0 { 0.5 } else { 0.05 };
            let lits = complement_lits(&mut rng, 16, density);
            let want = tr.scores(&lits);
            let mut got = vec![0i32; 3];
            snap.scores_into(&mut scratch, &lits, &mut got);
            assert_eq!(got, want, "auto, trial {trial}");
            for mode in [InferMode::Dense, InferMode::Sparse] {
                snap.score_into(&mut scratch, mode, &lits, &mut got);
                assert_eq!(got, want, "{} trial {trial}", mode.name());
            }
        }
    }

    #[test]
    fn forced_mode_snapshots_score_with_single_index() {
        let mut tr = trained(8);
        let dense_only = ModelSnapshot::with_mode(tr.tm.clone(), 9, InferMode::Dense);
        let sparse_only = ModelSnapshot::with_mode(tr.tm.clone(), 9, InferMode::Sparse);
        assert_eq!(dense_only.infer_mode(), InferMode::Dense);
        assert_eq!(sparse_only.infer_mode(), InferMode::Sparse);
        let mut ds = dense_only.make_scratch();
        let mut ss = sparse_only.make_scratch();
        let mut rng = Rng::new(13);
        for _ in 0..20 {
            let lits = complement_lits(&mut rng, 16, 0.3);
            let want = tr.scores(&lits);
            let mut got = vec![0i32; 3];
            dense_only.scores_into(&mut ds, &lits, &mut got);
            assert_eq!(got, want, "dense-forced");
            sparse_only.scores_into(&mut ss, &lits, &mut got);
            assert_eq!(got, want, "sparse-forced");
        }
        // switching policy on an existing snapshot builds what it needs
        let back_to_auto = sparse_only.with_infer_mode(InferMode::Auto);
        let mut scratch = back_to_auto.make_scratch();
        let lits = complement_lits(&mut rng, 16, 0.6); // dense input
        let want = tr.scores(&lits);
        let mut got = vec![0i32; 3];
        back_to_auto.scores_into(&mut scratch, &lits, &mut got);
        assert_eq!(got, want);
    }

    #[test]
    fn resolve_mode_follows_density_and_structure() {
        let mut tr = trained(6);
        let snap = tr.publish();
        let mut rng = Rng::new(11);
        let sparse_in = complement_lits(&mut rng, 16, 0.03);
        let dense_in = complement_lits(&mut rng, 16, 0.6);
        assert_eq!(snap.resolve_mode([&sparse_in]), InferMode::Sparse);
        assert_eq!(snap.resolve_mode([&dense_in]), InferMode::Dense);
        // non-complement input always resolves dense
        let raw = BitVec::ones(32);
        assert_eq!(snap.resolve_mode([&raw]), InferMode::Dense);
        // empty probe resolves dense
        assert_eq!(
            snap.resolve_mode(std::iter::empty::<&BitVec>()),
            InferMode::Dense
        );
        // forced mode passes through
        let forced = ModelSnapshot::new(tr.tm.clone(), 7).with_infer_mode(InferMode::Sparse);
        assert_eq!(forced.resolve_mode([&dense_in]), InferMode::Sparse);
        assert_eq!(forced.version(), 7);
    }

    #[test]
    fn publish_versions_are_monotonic_and_frozen() {
        let mut tr = trained(7);
        let v1 = tr.publish();
        // keep training: the published snapshot must not move
        let mut rng = Rng::new(21);
        let probe = complement_lits(&mut rng, 16, 0.4);
        let mut scratch = v1.make_scratch();
        let mut before = vec![0i32; 3];
        v1.scores_into(&mut scratch, &probe, &mut before);
        let more: Vec<(BitVec, usize)> = (0..80)
            .map(|_| (complement_lits(&mut rng, 16, 0.3), rng.below(3) as usize))
            .collect();
        tr.train_epoch(more.iter().map(|(l, y)| (l, *y)));
        let v2 = tr.publish();
        assert_eq!(v1.version(), 1);
        assert_eq!(v2.version(), 2);
        let mut after = vec![0i32; 3];
        v1.scores_into(&mut scratch, &probe, &mut after);
        assert_eq!(before, after, "published snapshot drifted under training");
        // and the new snapshot tracks the trained machine
        let mut scratch2 = v2.make_scratch();
        let mut got = vec![0i32; 3];
        v2.scores_into(&mut scratch2, &probe, &mut got);
        assert_eq!(got, tr.scores(&probe));
    }
}
