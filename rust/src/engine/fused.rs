//! Class-fused clause index: one falsification walk per sample knocks
//! out clauses of *every* class at once.
//!
//! The per-class [`crate::index::ClassIndex`] pays the falsification
//! walk once per class per sample: the same false-literal enumeration
//! runs `m` times and each class chases its own inclusion lists. The
//! fused index concatenates all classes' lists into one CSR-style
//! layout over a **global clause-id space**
//!
//! ```text
//! gid = class * clauses_per_class + local_id
//! ```
//!
//! so row `L_k` holds every clause (of every class) that includes
//! literal `k`. A single walk over a sample's false non-empty literals
//! then subtracts each falsified clause's vote from its class's
//! accumulator — `m` class scores from one pass. Because
//! `clauses_per_class` is even, `gid` parity equals local parity and
//! [`ClauseBank::polarity`] applies to global ids unchanged.
//!
//! Maintenance is the paper's O(1) insertion/deletion algebra on the
//! same [`ListStore`]/[`PositionStore`] pair the per-class index uses;
//! [`FusedIndex`] implements [`FlipSink`] (with global clause ids) so a
//! training loop can keep it live. Serving snapshots skip the position
//! matrix entirely ([`Maintenance::Frozen`]) — inference never deletes,
//! and the matrix is the index's dominant memory cost.

use crate::eval::traits::FlipSink;
use crate::index::liststore::ListStore;
use crate::index::position::PositionStore;
use crate::obs::ProbeDelta;
use crate::tm::bank::ClauseBank;
use crate::tm::classifier::MultiClassTM;
use crate::tm::params::TMParams;
use crate::util::bitvec::words_for;
use crate::util::simd::{self, SimdMode};
use crate::util::BitVec;

/// Does the index carry the position matrix needed for O(1) deletes?
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Maintenance {
    /// Read-only inference snapshot: no position matrix, flips panic.
    /// Rebuild (or construct a fresh index) after training steps.
    Frozen,
    /// Full paper-style maintenance: O(1) insert/delete via the
    /// position matrix; accepts [`FlipSink`] events with global ids.
    Maintained,
}

/// Per-global-clause constants read on the knock-out hot path: the
/// signed weighted vote and the owning class, packed together so one
/// cache line serves 8 clauses.
#[derive(Clone, Copy, Debug)]
struct ClauseMeta {
    vote: i32,
    class: u32,
}

/// Word budget (`u64`s, 16 MiB) for the literal→clause bitmap plane
/// under [`SimdMode::Auto`]. The plane costs
/// `n_literals * ceil(total_clauses / 64)` words; within this budget
/// the wide walk is a clear win (MNIST-scale models sit around half a
/// megabyte), beyond it `auto` falls back to the scalar CSR walk and
/// only an explicit `--simd wide` forces the plane.
pub const AUTO_PLANE_WORD_CAP: usize = 1 << 21;

/// Dense mirror of the CSR lists for the SIMD walk: row `k` is a
/// `total_clauses`-bit bitmap of the clauses including literal `k`.
/// The wide [`FusedIndex::score_into`] path ORs the rows of the
/// sample's false non-empty literals into one falsified-clause bitmap
/// (no gen-stamp dedup — OR is idempotent) and scores it with masked
/// popcounts. Kept bit-for-bit in sync with the lists by
/// [`FusedIndex::insert`] / [`FusedIndex::delete`].
#[derive(Clone, Debug)]
struct ClausePlane {
    /// Words per literal row: `ceil(total_clauses / 64)`.
    row_words: usize,
    /// `n_literals * row_words` bitmap words, row-major by literal.
    bits: Vec<u64>,
    /// True while every clause's vote equals its polarity (all weights
    /// 1): scoring is then a signed parity popcount per class
    /// ([`simd::parity_vote_in_range`]). Conservatively cleared on any
    /// weight change and recomputed on rebuild; when false, the wide
    /// path iterates the falsified bitmap's set bits against `meta`.
    uniform_votes: bool,
}

impl ClausePlane {
    #[inline]
    fn row(&self, k: usize) -> &[u64] {
        &self.bits[k * self.row_words..(k + 1) * self.row_words]
    }

    #[inline]
    fn set(&mut self, k: usize, gid: u32) {
        self.bits[k * self.row_words + (gid as usize >> 6)] |= 1u64 << (gid & 63);
    }

    #[inline]
    fn clear(&mut self, k: usize, gid: u32) {
        self.bits[k * self.row_words + (gid as usize >> 6)] &= !(1u64 << (gid & 63));
    }
}

/// Decide whether a plane is built for this mode and geometry:
/// `wide` always, `scalar` never, `auto` within the memory budget.
fn plane_for(simd: SimdMode, total_clauses: usize, n_literals: usize) -> Option<ClausePlane> {
    let row_words = words_for(total_clauses);
    let build = match simd {
        SimdMode::Scalar => false,
        SimdMode::Wide => true,
        SimdMode::Auto => n_literals.saturating_mul(row_words) <= AUTO_PLANE_WORD_CAP,
    };
    build.then(|| ClausePlane {
        row_words,
        bits: vec![0; n_literals * row_words],
        uniform_votes: true,
    })
}

/// The fused index: all classes' inclusion lists in one global-id CSR
/// layout, plus per-class vote baselines.
#[derive(Clone, Debug)]
pub struct FusedIndex {
    classes: usize,
    clauses_per_class: usize,
    n_literals: usize,
    /// `L_k` rows over global clause ids.
    lists: ListStore,
    /// `M[gid][k]` — only in [`Maintenance::Maintained`] mode.
    pos: Option<PositionStore>,
    /// Literals whose global list is non-empty (walk skip mask).
    nonempty: BitVec,
    /// Per-class weighted vote sum over non-empty clauses — the
    /// all-true inference score before any falsification.
    vote_alive: Vec<i32>,
    /// Per-global-clause vote + class.
    meta: Vec<ClauseMeta>,
    /// Requested SIMD mode (from `TMParams::simd`).
    simd: SimdMode,
    /// Bitmap mirror for the wide walk — present iff the mode and the
    /// memory budget allow (see [`plane_for`]).
    plane: Option<ClausePlane>,
}

/// Prefetch the cache line at `p` (no-op off x86_64).
#[inline(always)]
fn prefetch(p: *const u32) {
    #[cfg(target_arch = "x86_64")]
    unsafe {
        core::arch::x86_64::_mm_prefetch(p as *const i8, core::arch::x86_64::_MM_HINT_T0);
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = p;
}

impl FusedIndex {
    /// Empty index for a fresh machine.
    pub fn new(params: &TMParams, maintenance: Maintenance) -> Self {
        let total = params.total_clauses();
        let n_lit = params.n_literals();
        FusedIndex {
            classes: params.classes,
            clauses_per_class: params.clauses_per_class,
            n_literals: n_lit,
            lists: ListStore::auto(total, n_lit),
            pos: match maintenance {
                Maintenance::Maintained => Some(PositionStore::auto(total, n_lit)),
                Maintenance::Frozen => None,
            },
            nonempty: BitVec::zeros(n_lit),
            vote_alive: vec![0; params.classes],
            meta: (0..total)
                .map(|g| ClauseMeta {
                    vote: ClauseBank::polarity(g),
                    class: (g / params.clauses_per_class) as u32,
                })
                .collect(),
            simd: params.simd,
            plane: plane_for(params.simd, total, n_lit),
        }
    }

    /// Build from a trained machine.
    pub fn from_machine(tm: &MultiClassTM, maintenance: Maintenance) -> Self {
        let mut idx = FusedIndex::new(&tm.params, maintenance);
        idx.rebuild(tm);
        idx
    }

    /// Rebuild all derived state from the machine's banks.
    pub fn rebuild(&mut self, tm: &MultiClassTM) {
        let params = &tm.params;
        assert_eq!(params.classes, self.classes);
        assert_eq!(params.clauses_per_class, self.clauses_per_class);
        let total = params.total_clauses();
        let n_lit = params.n_literals();
        self.lists = ListStore::auto(total, n_lit);
        if self.pos.is_some() {
            self.pos = Some(PositionStore::auto(total, n_lit));
        }
        self.nonempty = BitVec::zeros(n_lit);
        self.vote_alive = vec![0; self.classes];
        for c in 0..self.classes {
            let bank = tm.bank(c);
            for j in 0..bank.clauses() {
                let gid = self.global_id(c, j);
                self.meta[gid as usize] = ClauseMeta {
                    vote: bank.vote(j),
                    class: c as u32,
                };
                if bank.count(j) > 0 {
                    self.vote_alive[c] += bank.vote(j);
                }
                for k in bank.included_literals(j) {
                    let p = self.lists.push(k, gid);
                    if let Some(pos) = &mut self.pos {
                        pos.set(gid, k as u32, p);
                    }
                    if p == 0 {
                        self.nonempty.set(k);
                    }
                }
            }
        }
        // mirror the rebuilt lists into the bitmap plane and recompute
        // the uniform-votes fast-path flag
        self.plane = plane_for(self.simd, total, n_lit);
        if let Some(plane) = &mut self.plane {
            for k in 0..n_lit {
                for &gid in self.lists.row(k) {
                    plane.set(k, gid);
                }
            }
            plane.uniform_votes = self
                .meta
                .iter()
                .enumerate()
                .all(|(g, m)| m.vote == ClauseBank::polarity(g));
        }
    }

    /// Global clause id of `(class, local clause)`.
    #[inline]
    pub fn global_id(&self, class: usize, j: usize) -> u32 {
        (class * self.clauses_per_class + j) as u32
    }

    #[inline]
    /// Number of classes fused into this index.
    pub fn classes(&self) -> usize {
        self.classes
    }

    #[inline]
    /// Clauses per class (uniform across classes).
    pub fn clauses_per_class(&self) -> usize {
        self.clauses_per_class
    }

    #[inline]
    /// Total clauses across every class (the global-id space).
    pub fn total_clauses(&self) -> usize {
        self.classes * self.clauses_per_class
    }

    #[inline]
    /// Number of literals (2 × features) per clause.
    pub fn n_literals(&self) -> usize {
        self.n_literals
    }

    /// Per-class all-true vote baselines.
    pub fn vote_alive(&self) -> &[i32] {
        &self.vote_alive
    }

    /// The global inclusion list of literal `k`.
    #[inline]
    pub fn list(&self, k: usize) -> &[u32] {
        self.lists.row(k)
    }

    /// True if the position matrix is kept for O(1) maintenance.
    pub fn is_maintained(&self) -> bool {
        self.pos.is_some()
    }

    /// Approximate resident bytes (capacity diagnostics).
    pub fn footprint_bytes(&self) -> usize {
        self.lists.footprint_bytes()
            + self.pos.as_ref().map_or(0, |p| p.footprint_bytes())
            + self.meta.len() * std::mem::size_of::<ClauseMeta>()
    }

    fn pos_mut(&mut self) -> &mut PositionStore {
        self.pos
            .as_mut()
            .expect("frozen FusedIndex cannot accept flips; build with Maintenance::Maintained")
    }

    /// O(1) insertion (TA flipped exclude -> include), global clause id.
    pub fn insert(&mut self, gid: u32, k: u32, new_count: u32, weight: u32) {
        if let Some(p) = &self.pos {
            debug_assert!(p.get(gid, k).is_none(), "duplicate insert ({gid},{k})");
        }
        let p = self.lists.push(k as usize, gid);
        self.pos_mut().set(gid, k, p);
        if p == 0 {
            self.nonempty.set(k as usize);
        }
        if let Some(plane) = &mut self.plane {
            plane.set(k as usize, gid);
        }
        if new_count == 1 {
            let class = self.meta[gid as usize].class as usize;
            self.vote_alive[class] += ClauseBank::polarity(gid as usize) * weight as i32;
        }
    }

    /// O(1) deletion by swap-with-last, global clause id.
    pub fn delete(&mut self, gid: u32, k: u32, new_count: u32, weight: u32) {
        let p = self
            .pos_mut()
            .remove(gid, k)
            .expect("delete of unindexed (clause, literal)");
        if let Some(moved) = self.lists.swap_remove(k as usize, p) {
            self.pos_mut().set(moved, k, p);
        }
        if self.lists.lens()[k as usize] == 0 {
            self.nonempty.clear(k as usize);
        }
        if let Some(plane) = &mut self.plane {
            plane.clear(k as usize, gid);
        }
        if new_count == 0 {
            let class = self.meta[gid as usize].class as usize;
            self.vote_alive[class] -= ClauseBank::polarity(gid as usize) * weight as i32;
        }
    }

    /// Weight change of global clause `gid` (weighted TMs).
    pub fn weight_changed(&mut self, gid: u32, delta: i32, nonempty: bool) {
        let d = ClauseBank::polarity(gid as usize) * delta;
        let m = &mut self.meta[gid as usize];
        m.vote += d;
        if nonempty {
            self.vote_alive[m.class as usize] += d;
        }
        // conservatively drop the parity-popcount fast path: weights in
        // play means per-clause votes (rebuild recomputes the flag)
        if let Some(plane) = &mut self.plane {
            plane.uniform_votes = false;
        }
    }

    /// Iterate the indices of FALSE literals whose global list is
    /// non-empty: `(!literals & nonempty)`, word-parallel.
    #[inline]
    pub fn walk_false_nonempty<'a>(
        &'a self,
        literals: &'a BitVec,
    ) -> impl Iterator<Item = usize> + 'a {
        literals
            .words()
            .iter()
            .zip(self.nonempty.words())
            .enumerate()
            .flat_map(|(wi, (&lw, &ne))| {
                // nonempty's tail bits are 0, masking !lw's padding.
                let mut w = !lw & ne;
                std::iter::from_fn(move || {
                    if w == 0 {
                        return None;
                    }
                    let bit = w.trailing_zeros() as usize;
                    w &= w - 1;
                    Some(wi * 64 + bit)
                })
            })
    }

    /// Fresh scratch sized for this index.
    pub fn make_scratch(&self) -> FusedScratch {
        FusedScratch::new(self.total_clauses())
    }

    /// Score one sample against **all classes** in a single walk,
    /// writing class `c`'s inference score to `out[c]`.
    ///
    /// Bit-identical to running [`crate::index::IndexedEval::score`]
    /// per class: `out[c] = vote_alive[c] - Σ votes of c's falsified
    /// non-empty clauses` in exact integer arithmetic.
    ///
    /// With a bitmap plane present (see [`plane_for`]) the walk runs
    /// the wide path instead: OR-accumulate the false non-empty
    /// literals' clause bitmaps ([`simd::or_accumulate`]) and score the
    /// falsified set with per-class masked popcounts — identical
    /// scores and probe counts, integer-exact.
    pub fn score_into(&self, scratch: &mut FusedScratch, literals: &BitVec, out: &mut [i32]) {
        assert_eq!(out.len(), self.classes);
        assert_eq!(literals.len(), self.n_literals);
        if self.plane.is_some() {
            return self.score_into_wide(scratch, literals, out);
        }
        debug_assert_eq!(scratch.gen.len(), self.total_clauses());
        out.copy_from_slice(&self.vote_alive);
        let FusedScratch {
            gen,
            cur_gen,
            walk,
            probes,
            ..
        } = scratch;
        *cur_gen = cur_gen.wrapping_add(1);
        if *cur_gen == 0 {
            // wrapped: stamps from 4 billion evals ago could collide
            gen.fill(0);
            *cur_gen = 1;
        }
        let stamp = *cur_gen;
        walk.clear();
        walk.extend(self.walk_false_nonempty(literals).map(|k| k as u32));
        let mut falsified: u64 = 0;
        const LOOKAHEAD: usize = 8;
        for (i, &k) in walk.iter().enumerate() {
            if let Some(&kn) = walk.get(i + LOOKAHEAD) {
                prefetch(self.lists.row_ptr(kn as usize));
            }
            for &gid in self.lists.row(k as usize) {
                let g = &mut gen[gid as usize];
                if *g != stamp {
                    *g = stamp;
                    falsified += 1;
                    let m = self.meta[gid as usize];
                    out[m.class as usize] -= m.vote;
                }
            }
        }
        // Index-efficiency probes: plain adds on a per-sample scratch —
        // no atomics on the hot path; the batch worker flushes them.
        probes.dense_samples += 1;
        probes.features_walked += walk.len() as u64;
        probes.clauses_falsified += falsified;
        probes.clauses_skipped += self.meta.len() as u64 - falsified;
    }

    /// The SIMD walk: instead of chasing CSR rows clause-by-clause with
    /// gen-stamp dedup, OR each false non-empty literal's clause bitmap
    /// into one falsified set (idempotent — no dedup state needed),
    /// then subtract the falsified vote mass per class: a signed parity
    /// popcount over the class's gid range when votes are uniform
    /// (interleaved polarity makes even bits `+1`, odd bits `-1`), or a
    /// set-bit iteration against `meta` for weighted machines. Probe
    /// counts match the scalar walk exactly (`clauses_falsified` is the
    /// popcount of the deduplicated set either way).
    fn score_into_wide(&self, scratch: &mut FusedScratch, literals: &BitVec, out: &mut [i32]) {
        let plane = self.plane.as_ref().expect("wide walk requires a plane");
        out.copy_from_slice(&self.vote_alive);
        let FusedScratch {
            walk,
            falsified,
            probes,
            ..
        } = scratch;
        if falsified.len() != plane.row_words {
            falsified.resize(plane.row_words, 0);
        }
        falsified.fill(0);
        walk.clear();
        walk.extend(self.walk_false_nonempty(literals).map(|k| k as u32));
        const LOOKAHEAD: usize = 2;
        for (i, &k) in walk.iter().enumerate() {
            if let Some(&kn) = walk.get(i + LOOKAHEAD) {
                prefetch(plane.row(kn as usize).as_ptr() as *const u32);
            }
            simd::or_accumulate(falsified, plane.row(k as usize));
        }
        let knocked = simd::popcount_words(falsified);
        if plane.uniform_votes {
            let cpc = self.clauses_per_class;
            for (c, slot) in out.iter_mut().enumerate() {
                *slot -= simd::parity_vote_in_range(falsified, c * cpc, (c + 1) * cpc);
            }
        } else {
            for (wi, &word) in falsified.iter().enumerate() {
                let mut bits = word;
                while bits != 0 {
                    let b = bits.trailing_zeros() as usize;
                    bits &= bits - 1;
                    let m = self.meta[wi * 64 + b];
                    out[m.class as usize] -= m.vote;
                }
            }
        }
        probes.dense_samples += 1;
        probes.features_walked += walk.len() as u64;
        probes.clauses_falsified += knocked;
        probes.clauses_skipped += self.meta.len() as u64 - knocked;
    }

    /// Full structural invariant check against the machine (tests).
    #[doc(hidden)]
    pub fn check_invariants(&self, tm: &MultiClassTM) -> Result<(), String> {
        let n = self.clauses_per_class;
        // 1. every list entry is a real inclusion (and positioned, if
        //    maintained)
        for k in 0..self.n_literals {
            for (p, &gid) in self.lists.row(k).iter().enumerate() {
                let (c, j) = (gid as usize / n, gid as usize % n);
                if !tm.bank(c).include(j, k) {
                    return Err(format!("list {k} holds non-included clause {gid}"));
                }
                if let Some(pos) = &self.pos {
                    if pos.get(gid, k as u32) != Some(p as u32) {
                        return Err(format!("M[{gid}][{k}] != {p}"));
                    }
                }
            }
            let listed = self.lists.lens()[k] as usize;
            if self.nonempty.get(k) != (listed > 0) {
                return Err(format!("nonempty[{k}] out of sync (len {listed})"));
            }
        }
        // 2. every inclusion is listed, counts and votes agree
        let mut listed_total = 0usize;
        for c in 0..self.classes {
            let bank = tm.bank(c);
            for j in 0..n {
                let gid = self.global_id(c, j);
                if self.meta[gid as usize].vote != bank.vote(j) {
                    return Err(format!("meta vote of {gid} != bank vote"));
                }
                if self.meta[gid as usize].class != c as u32 {
                    return Err(format!("meta class of {gid} != {c}"));
                }
                for k in bank.included_literals(j) {
                    if !self.lists.row(k).contains(&gid) {
                        return Err(format!("missing list entry ({gid},{k})"));
                    }
                }
                listed_total += bank.count(j) as usize;
            }
            if self.vote_alive[c] != bank.vote_alive() {
                return Err(format!(
                    "vote_alive[{c}] {} != bank {}",
                    self.vote_alive[c],
                    bank.vote_alive()
                ));
            }
        }
        let listed: usize = self.lists.lens().iter().map(|&l| l as usize).sum();
        if listed != listed_total {
            return Err(format!("listed {listed} != included {listed_total}"));
        }
        // 3. the bitmap plane (when present) mirrors the lists exactly
        if let Some(plane) = &self.plane {
            if plane.row_words != words_for(self.total_clauses()) {
                return Err("plane row_words out of sync".into());
            }
            for k in 0..self.n_literals {
                let row = plane.row(k);
                let set: u64 = row.iter().map(|w| w.count_ones() as u64).sum();
                if set != self.lists.lens()[k] as u64 {
                    return Err(format!(
                        "plane row {k} popcount {set} != list len {}",
                        self.lists.lens()[k]
                    ));
                }
                for &gid in self.lists.row(k) {
                    if (row[gid as usize >> 6] >> (gid & 63)) & 1 == 0 {
                        return Err(format!("plane missing bit ({gid},{k})"));
                    }
                }
            }
            // uniform_votes may be conservatively false, never falsely true
            let uniform = self
                .meta
                .iter()
                .enumerate()
                .all(|(g, m)| m.vote == ClauseBank::polarity(g));
            if plane.uniform_votes && !uniform {
                return Err("plane claims uniform votes on a weighted machine".into());
            }
        }
        Ok(())
    }
}

impl FlipSink for FusedIndex {
    /// `j` is a **global** clause id (see [`FusedIndex::global_id`]).
    #[inline]
    fn on_include(&mut self, j: u32, k: u32, new_count: u32, weight: u32) {
        self.insert(j, k, new_count, weight);
    }
    #[inline]
    fn on_exclude(&mut self, j: u32, k: u32, new_count: u32, weight: u32) {
        self.delete(j, k, new_count, weight);
    }
    #[inline]
    fn on_weight(&mut self, j: u32, delta: i32, nonempty: bool) {
        self.weight_changed(j, delta, nonempty);
    }
}

/// Mutable per-evaluation state, separated from the read-only
/// [`FusedIndex`] so batch sharding can hand one scratch to each worker
/// thread while all workers share the index.
///
/// The generation-stamp trick deduplicates knock-outs without clearing
/// a `total_clauses`-sized array per sample: a clause is "already
/// falsified in this evaluation" iff its stamp equals the current
/// generation.
#[derive(Clone, Debug)]
pub struct FusedScratch {
    gen: Vec<u32>,
    cur_gen: u32,
    /// Reusable walk-target buffer (enables prefetch lookahead).
    walk: Vec<u32>,
    /// Falsified-clause bitmap of the wide walk (`row_words` words;
    /// lazily sized — empty until the first wide evaluation).
    falsified: Vec<u64>,
    /// Accumulated index-efficiency probe counters (plain adds; drained
    /// with [`FusedScratch::take_probes`]).
    probes: ProbeDelta,
}

impl FusedScratch {
    /// Scratch sized for an index of `total_clauses` global ids.
    pub fn new(total_clauses: usize) -> Self {
        FusedScratch {
            gen: vec![0; total_clauses],
            cur_gen: 0,
            walk: Vec::new(),
            falsified: Vec::new(),
            probes: ProbeDelta::default(),
        }
    }

    /// Resize for a rebuilt index (stamps are invalidated).
    pub fn reset(&mut self, total_clauses: usize) {
        self.gen.clear();
        self.gen.resize(total_clauses, 0);
        self.cur_gen = 0;
        self.walk.clear();
        self.falsified.clear();
        self.probes = ProbeDelta::default();
    }

    /// Drain the probe counters accumulated since the last call.
    pub fn take_probes(&mut self) -> ProbeDelta {
        self.probes.take()
    }

    #[doc(hidden)]
    pub fn force_generation(&mut self, g: u32) {
        self.cur_gen = g;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::traits::reference_score;
    use crate::util::Rng;

    fn random_machine(
        rng: &mut Rng,
        classes: usize,
        clauses: usize,
        features: usize,
    ) -> MultiClassTM {
        let mut tm = MultiClassTM::new(TMParams::new(classes, clauses, features));
        let n_lit = 2 * features;
        for c in 0..classes {
            let bank = tm.bank_mut(c);
            for j in 0..clauses {
                for k in 0..n_lit {
                    if rng.bern(0.15) {
                        bank.set_state(j, k, (rng.below(11) as i8) - 5);
                    }
                }
            }
        }
        tm
    }

    fn random_lits(rng: &mut Rng, n: usize) -> BitVec {
        BitVec::from_bools(&(0..n).map(|_| rng.bern(0.5)).collect::<Vec<_>>())
    }

    #[test]
    fn fused_scores_match_reference_per_class() {
        let mut rng = Rng::new(41);
        for trial in 0..40 {
            let tm = random_machine(&mut rng, 3, 8, 15);
            let idx = FusedIndex::from_machine(&tm, Maintenance::Frozen);
            let mut scratch = idx.make_scratch();
            let lits = random_lits(&mut rng, 30);
            let mut out = vec![0i32; 3];
            idx.score_into(&mut scratch, &lits, &mut out);
            for c in 0..3 {
                assert_eq!(
                    out[c],
                    reference_score(tm.bank(c), &lits, false),
                    "class {c} trial {trial}"
                );
            }
        }
    }

    #[test]
    fn scratch_reuse_is_clean_across_samples() {
        let mut rng = Rng::new(42);
        let tm = random_machine(&mut rng, 4, 10, 20);
        let idx = FusedIndex::from_machine(&tm, Maintenance::Frozen);
        let mut scratch = idx.make_scratch();
        let mut out = vec![0i32; 4];
        for _ in 0..50 {
            let lits = random_lits(&mut rng, 40);
            idx.score_into(&mut scratch, &lits, &mut out);
            for c in 0..4 {
                assert_eq!(out[c], reference_score(tm.bank(c), &lits, false));
            }
        }
    }

    #[test]
    fn generation_wraparound_is_safe() {
        let mut rng = Rng::new(43);
        let tm = random_machine(&mut rng, 2, 6, 12);
        let idx = FusedIndex::from_machine(&tm, Maintenance::Frozen);
        let mut scratch = idx.make_scratch();
        scratch.force_generation(u32::MAX - 2);
        let lits = random_lits(&mut rng, 24);
        let want: Vec<i32> = (0..2)
            .map(|c| reference_score(tm.bank(c), &lits, false))
            .collect();
        let mut out = vec![0i32; 2];
        for _ in 0..6 {
            idx.score_into(&mut scratch, &lits, &mut out);
            assert_eq!(out, want);
        }
    }

    #[test]
    fn all_true_input_gives_vote_alive_per_class() {
        let mut rng = Rng::new(44);
        let tm = random_machine(&mut rng, 3, 8, 10);
        let idx = FusedIndex::from_machine(&tm, Maintenance::Frozen);
        let mut scratch = idx.make_scratch();
        let mut out = vec![0i32; 3];
        idx.score_into(&mut scratch, &BitVec::ones(20), &mut out);
        assert_eq!(out, idx.vote_alive());
        for c in 0..3 {
            assert_eq!(out[c], tm.bank(c).vote_alive());
        }
    }

    #[test]
    fn maintained_index_tracks_flip_storm() {
        use crate::tm::bank::Flip;
        let mut rng = Rng::new(45);
        let classes = 3;
        let clauses = 8;
        let n_lit = 24;
        let mut tm = random_machine(&mut rng, classes, clauses, n_lit / 2);
        let mut idx = FusedIndex::from_machine(&tm, Maintenance::Maintained);
        for _ in 0..8000 {
            let c = rng.below(classes as u32) as usize;
            let j = rng.below(clauses as u32) as usize;
            let k = rng.below(n_lit as u32) as usize;
            let gid = idx.global_id(c, j);
            let bank = tm.bank_mut(c);
            if rng.bern(0.5) {
                if bank.bump_up(j, k) == Flip::Included {
                    let (count, weight) = (bank.count(j), bank.weight(j));
                    idx.on_include(gid, k as u32, count, weight);
                }
            } else if bank.bump_down(j, k) == Flip::Excluded {
                let (count, weight) = (bank.count(j), bank.weight(j));
                idx.on_exclude(gid, k as u32, count, weight);
            }
        }
        idx.check_invariants(&tm).unwrap();
        let mut scratch = idx.make_scratch();
        let mut out = vec![0i32; classes];
        let lits = random_lits(&mut rng, n_lit);
        idx.score_into(&mut scratch, &lits, &mut out);
        for c in 0..classes {
            assert_eq!(out[c], reference_score(tm.bank(c), &lits, false));
        }
    }

    #[test]
    fn weight_changes_flow_into_votes() {
        let mut tm = MultiClassTM::new(TMParams::new(2, 4, 3).with_weighted(true));
        // class 1, clause 2 (+ polarity): include literal 0, weight 3
        tm.bank_mut(1).set_state(2, 0, 0);
        tm.bank_mut(1).set_weight(2, 3);
        let mut idx = FusedIndex::from_machine(&tm, Maintenance::Maintained);
        idx.check_invariants(&tm).unwrap();
        assert_eq!(idx.vote_alive()[1], 3);
        // +2 weight through the sink
        tm.bank_mut(1).set_weight(2, 5);
        let gid = idx.global_id(1, 2);
        idx.on_weight(gid, 2, true);
        idx.check_invariants(&tm).unwrap();
        assert_eq!(idx.vote_alive()[1], 5);
        let mut scratch = idx.make_scratch();
        let mut out = vec![0i32; 2];
        idx.score_into(&mut scratch, &BitVec::ones(6), &mut out);
        assert_eq!(out, vec![0, 5]);
    }

    #[test]
    #[should_panic(expected = "frozen FusedIndex")]
    fn frozen_index_rejects_flips() {
        let tm = MultiClassTM::new(TMParams::new(2, 4, 3));
        let mut idx = FusedIndex::from_machine(&tm, Maintenance::Frozen);
        idx.on_include(0, 0, 1, 1);
    }

    #[test]
    fn plane_gating_follows_mode_and_budget() {
        // scalar: never; wide: always; auto: only within the word cap
        assert!(plane_for(SimdMode::Scalar, 64, 8).is_none());
        assert!(plane_for(SimdMode::Wide, 64, 8).is_some());
        assert!(plane_for(SimdMode::Auto, 64, 8).is_some());
        assert!(plane_for(SimdMode::Auto, 64, AUTO_PLANE_WORD_CAP + 1).is_none());
        // wide forces the plane past the auto budget (no allocation
        // concern at this size: 64 clauses -> 1 word rows)
        assert!(plane_for(SimdMode::Wide, 64, 8).is_some());
    }

    #[test]
    fn wide_walk_matches_scalar_walk_scores_and_probes() {
        let mut rng = Rng::new(46);
        for trial in 0..30 {
            // >64 total clauses so the falsified bitmap spans words
            let mut tm = random_machine(&mut rng, 3, 48, 20);
            tm.set_simd(SimdMode::Scalar);
            let scalar_idx = FusedIndex::from_machine(&tm, Maintenance::Frozen);
            assert!(scalar_idx.plane.is_none());
            tm.set_simd(SimdMode::Wide);
            let wide_idx = FusedIndex::from_machine(&tm, Maintenance::Frozen);
            assert!(wide_idx.plane.is_some());
            let mut s_scratch = scalar_idx.make_scratch();
            let mut w_scratch = wide_idx.make_scratch();
            let mut s_out = vec![0i32; 3];
            let mut w_out = vec![0i32; 3];
            for _ in 0..20 {
                let lits = random_lits(&mut rng, 40);
                scalar_idx.score_into(&mut s_scratch, &lits, &mut s_out);
                wide_idx.score_into(&mut w_scratch, &lits, &mut w_out);
                assert_eq!(s_out, w_out, "trial {trial}");
            }
            let sp = s_scratch.take_probes();
            let wp = w_scratch.take_probes();
            assert_eq!(sp.dense_samples, wp.dense_samples);
            assert_eq!(sp.features_walked, wp.features_walked);
            assert_eq!(sp.clauses_falsified, wp.clauses_falsified);
            assert_eq!(sp.clauses_skipped, wp.clauses_skipped);
        }
    }

    #[test]
    fn wide_walk_handles_weighted_votes() {
        // weights break vote uniformity: the wide path must fall back
        // to per-clause vote subtraction and still match the scalar walk
        let mut rng = Rng::new(47);
        let mut tm = MultiClassTM::new(TMParams::new(3, 10, 12).with_weighted(true));
        for c in 0..3 {
            let bank = tm.bank_mut(c);
            for j in 0..10 {
                for k in 0..24 {
                    if rng.bern(0.2) {
                        bank.set_state(j, k, (rng.below(11) as i8) - 5);
                    }
                }
                bank.set_weight(j, 1 + rng.below(5));
            }
        }
        tm.set_simd(SimdMode::Wide);
        let wide_idx = FusedIndex::from_machine(&tm, Maintenance::Frozen);
        assert!(!wide_idx.plane.as_ref().unwrap().uniform_votes);
        tm.set_simd(SimdMode::Scalar);
        let scalar_idx = FusedIndex::from_machine(&tm, Maintenance::Frozen);
        let mut s_scratch = scalar_idx.make_scratch();
        let mut w_scratch = wide_idx.make_scratch();
        let mut s_out = vec![0i32; 3];
        let mut w_out = vec![0i32; 3];
        for _ in 0..40 {
            let lits = random_lits(&mut rng, 24);
            scalar_idx.score_into(&mut s_scratch, &lits, &mut s_out);
            wide_idx.score_into(&mut w_scratch, &lits, &mut w_out);
            assert_eq!(s_out, w_out);
            for c in 0..3 {
                assert_eq!(w_out[c], reference_score(tm.bank(c), &lits, false));
            }
        }
    }

    #[test]
    fn maintained_wide_index_stays_in_sync_through_flips() {
        use crate::tm::bank::Flip;
        let mut rng = Rng::new(48);
        let mut tm = random_machine(&mut rng, 2, 70, 10); // 140 gids: multi-word rows
        tm.set_simd(SimdMode::Wide);
        let mut idx = FusedIndex::from_machine(&tm, Maintenance::Maintained);
        for _ in 0..6000 {
            let c = rng.below(2) as usize;
            let j = rng.below(70) as usize;
            let k = rng.below(20) as usize;
            let gid = idx.global_id(c, j);
            let bank = tm.bank_mut(c);
            if rng.bern(0.5) {
                if bank.bump_up(j, k) == Flip::Included {
                    let (count, weight) = (bank.count(j), bank.weight(j));
                    idx.on_include(gid, k as u32, count, weight);
                }
            } else if bank.bump_down(j, k) == Flip::Excluded {
                let (count, weight) = (bank.count(j), bank.weight(j));
                idx.on_exclude(gid, k as u32, count, weight);
            }
        }
        idx.check_invariants(&tm).unwrap();
        let mut scratch = idx.make_scratch();
        let mut out = vec![0i32; 2];
        for _ in 0..10 {
            let lits = random_lits(&mut rng, 20);
            idx.score_into(&mut scratch, &lits, &mut out);
            for c in 0..2 {
                assert_eq!(out[c], reference_score(tm.bank(c), &lits, false));
            }
        }
    }
}
