//! Batch sharding: split a batch across scoped worker threads that
//! share one read-only index.
//!
//! This replaces the coordinator's old clone-the-whole-machine replica
//! scheme for CPU inference. The index is immutable during scoring, so
//! workers need no locks and no model copies — each worker gets only a
//! per-worker scratch (generation stamps + walk buffers, a few hundred
//! KB at paper scale) and a disjoint slice of the output matrix. Memory
//! cost is `O(workers * total_clauses)` scratch instead of
//! `O(workers * model)`, and the scratches are pooled by the caller so
//! steady-state serving allocates nothing.
//!
//! The splitter is generic over [`ShardScorer`], so the dense
//! class-fused walk ([`FusedIndex`] over `BitVec` literal vectors) and
//! the O(nnz) sparse-delta walk ([`crate::engine::SparseFusedIndex`]
//! over either `BitVec`s or [`crate::data::SparseSample`]s) share one
//! threading implementation.

use crate::engine::fused::{FusedIndex, FusedScratch};
use crate::util::BitVec;

/// A read-only index that scores one sample of type `Sample` against
/// every class, using caller-owned mutable scratch — the contract the
/// generic batch splitter threads over.
pub trait ShardScorer<Sample: Sync>: Sync {
    /// Per-worker mutable evaluation state.
    type Scratch: Send;

    /// Number of classes `m` (one score per sample per class).
    fn classes(&self) -> usize;

    /// Score one sample into `out` (`out.len() == classes()`).
    fn score_one(&self, scratch: &mut Self::Scratch, sample: &Sample, out: &mut [i32]);
}

impl ShardScorer<BitVec> for FusedIndex {
    type Scratch = FusedScratch;

    fn classes(&self) -> usize {
        FusedIndex::classes(self)
    }

    #[inline]
    fn score_one(&self, scratch: &mut FusedScratch, literals: &BitVec, out: &mut [i32]) {
        self.score_into(scratch, literals, out);
    }
}

/// Score `batch` into the row-major `out` matrix
/// (`out[i * classes + c]` = class `c`'s score for sample `i`),
/// splitting the batch across one thread per scratch.
///
/// `out.len()` must equal `batch.len() * index.classes()`. With a
/// single scratch (or a single-sample batch) this degrades to the
/// serial loop with no thread spawn.
pub fn score_batch_sharded<Sample: Sync, S: ShardScorer<Sample>>(
    index: &S,
    scratches: &mut [S::Scratch],
    batch: &[Sample],
    out: &mut [i32],
) {
    let m = index.classes();
    assert_eq!(out.len(), batch.len() * m, "output matrix shape mismatch");
    assert!(!scratches.is_empty(), "need at least one scratch");
    let workers = if batch.is_empty() {
        1
    } else {
        scratches.len().min(batch.len())
    };
    if workers == 1 {
        score_chunk(index, &mut scratches[0], batch, out);
        return;
    }
    let chunk = batch.len().div_ceil(workers);
    let (spawned, last) = scratches[..workers].split_at_mut(workers - 1);
    std::thread::scope(|scope| {
        let mut rest_batch = batch;
        let mut rest_out = out;
        for scratch in spawned {
            let take = chunk.min(rest_batch.len());
            if take == 0 {
                break;
            }
            let (chunk_batch, rb) = rest_batch.split_at(take);
            let (chunk_out, ro) = std::mem::take(&mut rest_out).split_at_mut(take * m);
            rest_batch = rb;
            rest_out = ro;
            scope.spawn(move || score_chunk(index, scratch, chunk_batch, chunk_out));
        }
        // final chunk on the calling thread — it would otherwise idle
        // in the scope join, wasting one spawn per batch
        score_chunk(index, &mut last[0], rest_batch, rest_out);
    });
}

/// Serial scoring of a chunk (also the per-worker body).
fn score_chunk<Sample: Sync, S: ShardScorer<Sample>>(
    index: &S,
    scratch: &mut S::Scratch,
    batch: &[Sample],
    out: &mut [i32],
) {
    let m = index.classes();
    for (sample, row) in batch.iter().zip(out.chunks_mut(m)) {
        index.score_one(scratch, sample, row);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::fused::Maintenance;
    use crate::tm::classifier::MultiClassTM;
    use crate::tm::params::TMParams;
    use crate::util::Rng;

    fn setup(rng: &mut Rng) -> (MultiClassTM, FusedIndex) {
        let mut tm = MultiClassTM::new(TMParams::new(4, 10, 16));
        for c in 0..4 {
            let bank = tm.bank_mut(c);
            for j in 0..10 {
                for k in 0..32 {
                    if rng.bern(0.12) {
                        bank.set_state(j, k, 1);
                    }
                }
            }
        }
        let idx = FusedIndex::from_machine(&tm, Maintenance::Frozen);
        (tm, idx)
    }

    fn random_batch(rng: &mut Rng, n: usize, n_lit: usize) -> Vec<BitVec> {
        (0..n)
            .map(|_| BitVec::from_bools(&(0..n_lit).map(|_| rng.bern(0.5)).collect::<Vec<_>>()))
            .collect()
    }

    #[test]
    fn sharded_matches_serial_across_worker_counts() {
        let mut rng = Rng::new(91);
        let (_tm, idx) = setup(&mut rng);
        let batch = random_batch(&mut rng, 37, 32);
        let mut serial = vec![0i32; batch.len() * 4];
        let mut one = vec![idx.make_scratch()];
        score_batch_sharded(&idx, &mut one, &batch, &mut serial);
        for workers in [2usize, 3, 4, 8, 64] {
            let mut scratches: Vec<_> = (0..workers).map(|_| idx.make_scratch()).collect();
            let mut out = vec![0i32; batch.len() * 4];
            score_batch_sharded(&idx, &mut scratches, &batch, &mut out);
            assert_eq!(out, serial, "{workers} workers");
        }
    }

    #[test]
    fn tiny_batches_work() {
        let mut rng = Rng::new(92);
        let (tm, idx) = setup(&mut rng);
        let mut scratches: Vec<_> = (0..4).map(|_| idx.make_scratch()).collect();
        // empty batch
        score_batch_sharded(&idx, &mut scratches, &[] as &[BitVec], &mut []);
        // single sample
        let batch = random_batch(&mut rng, 1, 32);
        let mut out = vec![0i32; 4];
        score_batch_sharded(&idx, &mut scratches, &batch, &mut out);
        let want: Vec<i32> = (0..4)
            .map(|c| crate::eval::traits::reference_score(tm.bank(c), &batch[0], false))
            .collect();
        assert_eq!(out, want);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn wrong_output_shape_panics() {
        let mut rng = Rng::new(93);
        let (_tm, idx) = setup(&mut rng);
        let batch = random_batch(&mut rng, 2, 32);
        let mut scratches = vec![idx.make_scratch()];
        let mut out = vec![0i32; 3];
        score_batch_sharded(&idx, &mut scratches, &batch, &mut out);
    }
}
