//! The batch inference engine and the multi-class batch-scoring
//! contract.
//!
//! [`BatchScorer`] is the serving-facing API: one call scores a whole
//! batch of literal vectors against every class. [`FusedEngine`] is the
//! real implementation — a [`FusedIndex`] plus a pool of per-worker
//! scratches, so repeated batches allocate nothing and large batches
//! shard across threads. [`crate::tm::trainer::Trainer`] also
//! implements the trait (routing to a fused engine for the indexed
//! backend and falling back to per-class evaluation otherwise), which
//! is what keeps the naive/bitpacked ablation backends usable from the
//! same serving code path.

use crate::engine::fused::{FusedIndex, FusedScratch, Maintenance};
use crate::engine::shard::score_batch_sharded;
use crate::tm::classifier::MultiClassTM;
use crate::util::BitVec;

/// Below this many samples per worker, thread-spawn overhead dominates
/// the walk and the engine scores serially.
pub const MIN_SAMPLES_PER_WORKER: usize = 4;

/// Multi-class batch scoring: the contract the coordinator's CPU
/// backend and the bench harness serve through.
///
/// Scores are **bit-identical** to the per-sample, per-class
/// [`crate::eval::Evaluator::score`] path — batching and class fusion
/// are pure evaluation-order changes over exact integer arithmetic.
pub trait BatchScorer {
    /// Number of classes `m` (one score per class per sample).
    fn classes(&self) -> usize;

    /// Literal width `2o` every sample must have.
    fn n_literals(&self) -> usize;

    /// Score one sample into `out` (`out.len() == classes`).
    fn scores_into(&mut self, literals: &BitVec, out: &mut [i32]);

    /// Score a batch into the row-major matrix
    /// `out[i * classes + c]`. The default loops [`Self::scores_into`];
    /// implementations override it to reuse scratch and shard across
    /// threads.
    fn score_batch_into(&mut self, batch: &[BitVec], out: &mut [i32]) {
        let m = self.classes();
        assert_eq!(out.len(), batch.len() * m, "output matrix shape mismatch");
        for (lits, row) in batch.iter().zip(out.chunks_mut(m)) {
            self.scores_into(lits, row);
        }
    }

    /// Convenience allocating form: per-sample score vectors.
    fn score_batch(&mut self, batch: &[BitVec]) -> Vec<Vec<i32>> {
        let m = self.classes();
        let mut flat = vec![0i32; batch.len() * m];
        self.score_batch_into(batch, &mut flat);
        flat.chunks(m).map(|row| row.to_vec()).collect()
    }

    /// Argmax prediction for one sample (ties break to the lowest
    /// class id, matching [`crate::tm::trainer::Trainer::predict`]).
    fn predict_into(&mut self, literals: &BitVec, scores: &mut [i32]) -> usize {
        self.scores_into(literals, scores);
        argmax(scores)
    }
}

/// Lowest-index argmax over class scores.
#[inline]
pub fn argmax(scores: &[i32]) -> usize {
    let mut best = 0usize;
    let mut best_score = i32::MIN;
    for (i, &s) in scores.iter().enumerate() {
        if s > best_score {
            best_score = s;
            best = i;
        }
    }
    best
}

/// The batch inference engine: class-fused index + pooled scratches.
#[derive(Clone, Debug)]
pub struct FusedEngine {
    index: FusedIndex,
    /// One scratch per potential worker; `scratches[0]` doubles as the
    /// serial/single-sample scratch.
    scratches: Vec<FusedScratch>,
}

impl FusedEngine {
    /// Snapshot a machine for serving with `threads` workers
    /// (1 = serial). The index is frozen — rebuild after training.
    pub fn from_machine(tm: &MultiClassTM, threads: usize) -> Self {
        Self::with_maintenance(tm, threads, Maintenance::Frozen)
    }

    /// Build with an explicit maintenance mode
    /// ([`Maintenance::Maintained`] keeps O(1) flip support).
    pub fn with_maintenance(tm: &MultiClassTM, threads: usize, maintenance: Maintenance) -> Self {
        let index = FusedIndex::from_machine(tm, maintenance);
        let scratches = (0..threads.max(1)).map(|_| index.make_scratch()).collect();
        FusedEngine { index, scratches }
    }

    /// Wrap an existing index (tests, incremental maintenance).
    pub fn from_index(index: FusedIndex, threads: usize) -> Self {
        let scratches = (0..threads.max(1)).map(|_| index.make_scratch()).collect();
        FusedEngine { index, scratches }
    }

    /// Refresh the index from the machine's current banks (after
    /// training steps) without reallocating the scratch pool.
    pub fn rebuild(&mut self, tm: &MultiClassTM) {
        self.index.rebuild(tm);
        let total = self.index.total_clauses();
        for s in &mut self.scratches {
            s.reset(total);
        }
    }

    /// The underlying fused index.
    pub fn index(&self) -> &FusedIndex {
        &self.index
    }

    /// Mutable index access (flip maintenance in `Maintained` mode).
    pub fn index_mut(&mut self) -> &mut FusedIndex {
        &mut self.index
    }

    /// Configured worker count.
    pub fn threads(&self) -> usize {
        self.scratches.len()
    }

    /// Change the worker count (resizes the scratch pool).
    pub fn set_threads(&mut self, threads: usize) {
        let threads = threads.max(1);
        let total = self.index.total_clauses();
        self.scratches.resize_with(threads, || FusedScratch::new(total));
    }
}

impl BatchScorer for FusedEngine {
    fn classes(&self) -> usize {
        self.index.classes()
    }

    fn n_literals(&self) -> usize {
        self.index.n_literals()
    }

    fn scores_into(&mut self, literals: &BitVec, out: &mut [i32]) {
        self.index.score_into(&mut self.scratches[0], literals, out);
    }

    fn score_batch_into(&mut self, batch: &[BitVec], out: &mut [i32]) {
        let threads = self.scratches.len();
        let workers = if threads > 1 && batch.len() >= MIN_SAMPLES_PER_WORKER * threads {
            threads
        } else {
            1
        };
        score_batch_sharded(&self.index, &mut self.scratches[..workers], batch, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::traits::reference_score;
    use crate::tm::params::TMParams;
    use crate::util::Rng;

    fn random_machine(rng: &mut Rng) -> MultiClassTM {
        let mut tm = MultiClassTM::new(TMParams::new(5, 12, 20));
        for c in 0..5 {
            let bank = tm.bank_mut(c);
            for j in 0..12 {
                for k in 0..40 {
                    if rng.bern(0.1) {
                        bank.set_state(j, k, 2);
                    }
                }
            }
        }
        tm
    }

    fn random_batch(rng: &mut Rng, n: usize) -> Vec<BitVec> {
        (0..n)
            .map(|_| BitVec::from_bools(&(0..40).map(|_| rng.bern(0.5)).collect::<Vec<_>>()))
            .collect()
    }

    #[test]
    fn engine_batch_matches_reference() {
        let mut rng = Rng::new(71);
        let tm = random_machine(&mut rng);
        let mut eng = FusedEngine::from_machine(&tm, 2);
        let batch = random_batch(&mut rng, 40);
        let got = eng.score_batch(&batch);
        assert_eq!(got.len(), 40);
        for (i, lits) in batch.iter().enumerate() {
            for c in 0..5 {
                assert_eq!(got[i][c], reference_score(tm.bank(c), lits, false));
            }
        }
    }

    #[test]
    fn serial_and_threaded_engines_agree() {
        let mut rng = Rng::new(72);
        let tm = random_machine(&mut rng);
        let batch = random_batch(&mut rng, 64);
        let mut serial = FusedEngine::from_machine(&tm, 1);
        let want = serial.score_batch(&batch);
        for threads in [2usize, 4, 7] {
            let mut eng = FusedEngine::from_machine(&tm, threads);
            assert_eq!(eng.threads(), threads);
            assert_eq!(eng.score_batch(&batch), want, "{threads} threads");
        }
    }

    #[test]
    fn rebuild_tracks_machine_changes() {
        let mut rng = Rng::new(73);
        let mut tm = random_machine(&mut rng);
        let mut eng = FusedEngine::from_machine(&tm, 2);
        let batch = random_batch(&mut rng, 8);
        let _ = eng.score_batch(&batch);
        // mutate the machine, rebuild, scores must track
        tm.bank_mut(3).set_state(0, 5, 1);
        tm.bank_mut(1).set_state(2, 7, 1);
        eng.rebuild(&tm);
        for lits in &batch {
            let mut out = vec![0i32; 5];
            eng.scores_into(lits, &mut out);
            for c in 0..5 {
                assert_eq!(out[c], reference_score(tm.bank(c), lits, false));
            }
        }
    }

    #[test]
    fn set_threads_reshapes_pool() {
        let mut rng = Rng::new(74);
        let tm = random_machine(&mut rng);
        let mut eng = FusedEngine::from_machine(&tm, 1);
        eng.set_threads(3);
        assert_eq!(eng.threads(), 3);
        let batch = random_batch(&mut rng, 24);
        let got = eng.score_batch(&batch);
        for (i, lits) in batch.iter().enumerate() {
            for c in 0..5 {
                assert_eq!(got[i][c], reference_score(tm.bank(c), lits, false));
            }
        }
        eng.set_threads(0); // clamps to 1
        assert_eq!(eng.threads(), 1);
    }

    #[test]
    fn argmax_breaks_ties_low() {
        assert_eq!(argmax(&[3, 7, 7, 1]), 1);
        assert_eq!(argmax(&[-5]), 0);
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn predict_into_matches_scores() {
        let mut rng = Rng::new(75);
        let tm = random_machine(&mut rng);
        let mut eng = FusedEngine::from_machine(&tm, 1);
        let batch = random_batch(&mut rng, 10);
        let mut scores = vec![0i32; 5];
        for lits in &batch {
            let p = eng.predict_into(lits, &mut scores);
            assert_eq!(p, argmax(&scores));
        }
    }
}
