//! Batched, class-fused inference engine.
//!
//! The paper's index (see [`crate::index`]) evaluates one class's
//! clauses by falsification. Serving wants more: score **all classes
//! for a whole batch** as cheaply as possible. This module supplies
//! that layer:
//!
//! * [`fused`] — [`FusedIndex`]: every class's inclusion lists
//!   concatenated into one CSR layout over a global clause-id space, so
//!   a single falsification walk per sample updates all `m` class
//!   accumulators. O(1) insert/delete is preserved
//!   ([`Maintenance::Maintained`]); serving snapshots drop the position
//!   matrix ([`Maintenance::Frozen`]).
//! * [`batch`] — the [`BatchScorer`] contract (with a loop-`score`
//!   default so every evaluator backend participates) and
//!   [`FusedEngine`], which pools per-worker scratch across calls.
//! * [`shard`] — scoped-thread batch splitting over a shared read-only
//!   index ([`ShardScorer`]): per-worker scratch, zero locks, zero
//!   model copies — replacing the old clone-per-replica serving scheme.
//! * [`sparse`] — [`SparseFusedIndex`]/[`SparseEngine`]: the O(nnz)
//!   sparse-delta walk for k-hot workloads — per-class all-zeros
//!   baseline scores plus per-literal delta lists, so scoring touches
//!   only the *set* features. [`InferMode`] selects between the dense
//!   and sparse engines (auto-picking by input density).
//! * [`snapshot`] — [`ModelSnapshot`]: an immutable, versioned freeze
//!   of a machine plus both engines' read-only indexes, shared behind
//!   an `Arc` so the serving coordinator can hot-swap model versions
//!   under live traffic with zero torn requests.
//!
//! The decomposition mirrors the class/clause-parallel architecture of
//! *Massively Parallel and Asynchronous Tsetlin Machine Architecture*
//! (arXiv 2009.04861) applied to the clause-indexed evaluator of the
//! source paper (arXiv 2004.03188); the sparse-delta path exploits the
//! weighted-clause compression of arXiv 1911.12607 (one skipped
//! falsification saves a multi-vote list entry).

pub mod batch;
pub mod fused;
pub mod shard;
pub mod snapshot;
pub mod sparse;

pub use batch::{argmax, BatchScorer, FusedEngine};
pub use fused::{FusedIndex, FusedScratch, Maintenance};
pub use shard::{score_batch_sharded, ShardScorer};
pub use snapshot::{ModelSnapshot, SnapshotScratch};
pub use sparse::{
    resolve_infer_mode, InferMode, SparseEngine, SparseFusedIndex, SparseScratch,
    SPARSE_DENSITY_THRESHOLD,
};
