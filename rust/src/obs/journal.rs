//! Bounded structured event journal.
//!
//! A process-wide ring buffer of typed operational events — snapshot
//! swaps, worker restarts, quarantines, shed episodes, drains — each
//! stamped with a monotonic offset (ordering) and a wall clock
//! (correlation with external logs). Registry, supervisor, and
//! coordinator all emit into the one [`journal`]; the serving layer
//! drains it via the `stats events <model>` verb and dumps it on
//! shutdown, so even a `kill -9` recovery leaves an inspectable trail
//! on the next run.
//!
//! Capacity-bounded: when full, the oldest event is evicted and a
//! dropped counter keeps the loss visible. Emission never panics —
//! lock poisoning recovers via `PoisonError::into_inner` like the
//! serving queue.

use std::collections::VecDeque;
use std::sync::{Mutex, OnceLock, PoisonError};
use std::time::{Instant, SystemTime, UNIX_EPOCH};

/// Default ring capacity (events) for the process journal.
pub const DEFAULT_CAPACITY: usize = 1024;

/// Typed operational events. Route-scoped variants carry the route
/// name; [`EventKind::route`] is `None` for process-wide events.
#[derive(Clone, Debug, PartialEq)]
pub enum EventKind {
    /// A route atomically swapped to a new model snapshot.
    SnapshotSwap {
        /// Route that swapped.
        route: String,
        /// Publisher-assigned snapshot version now serving.
        version: u64,
        /// The route's monotonic swap counter after the swap.
        generation: u64,
    },
    /// The supervisor restarted a panicked worker.
    WorkerRestart {
        /// Route whose worker restarted.
        route: String,
        /// Successful restarts so far for the route.
        restarts: u64,
    },
    /// The registry quarantined a torn/corrupt snapshot file.
    Quarantine {
        /// Route the damaged file belonged to.
        route: String,
        /// Version of the quarantined file.
        version: u64,
        /// Why it was quarantined (truncated, corrupt, …).
        reason: String,
    },
    /// A route was recovered (registry manifest / watch reload).
    RouteRecovered {
        /// Route that was recovered.
        route: String,
        /// Version now being served.
        version: u64,
    },
    /// A route failed to load and was skipped or kept on its old
    /// snapshot (the `error` says why).
    RouteFailed {
        /// Route that failed to load.
        route: String,
        /// Human-readable failure.
        error: String,
    },
    /// First shed after a healthy period: a shed episode began.
    ShedStart {
        /// Route that began shedding.
        route: String,
        /// Trace id of the first shed request.
        trace: u64,
    },
    /// First successful admission after shedding: episode over.
    ShedEnd {
        /// Route that recovered.
        route: String,
        /// Requests shed during the episode.
        shed_total: u64,
    },
    /// `--watch` picked up a changed model file and reloaded it.
    WatchReload {
        /// Route that reloaded.
        route: String,
        /// Version picked up from disk.
        version: u64,
    },
    /// `--watch` saw a change but kept serving the old snapshot.
    WatchFallback {
        /// Route that kept its old snapshot.
        route: String,
        /// Why the new file was rejected.
        error: String,
    },
    /// The online learner republished after `updates` feedback events
    /// (publish cadence, `--publish-every`/`--publish-interval`).
    FeedbackPublish {
        /// Route that republished.
        route: String,
        /// Newly published snapshot version.
        version: u64,
        /// The route's swap counter after the publish.
        generation: u64,
        /// Feedback events folded into this publish.
        updates: u64,
    },
    /// Restart replayed `records` feedback-WAL events into the route's
    /// recovered trainer before serving resumed. `stale` counts
    /// records the recovered snapshot already owned (skipped — the
    /// publish-before-truncate crash window, benign); `skipped` counts
    /// foreign/corrupt records (bad label or width — operator-visible
    /// before the log is truncated away).
    WalReplay {
        /// Route whose WAL was replayed.
        route: String,
        /// Records applied through the trainer.
        records: u64,
        /// Records the recovered snapshot already owned (skipped).
        stale: u64,
        /// Foreign/corrupt records dropped with a warning.
        skipped: u64,
    },
    /// The serve loop began draining (signal or shutdown).
    Drain {
        /// What triggered the drain (signal name, shutdown call).
        reason: String,
    },
    /// Control plane: a node answered a heartbeat after being down (or
    /// was seen for the first time) — admitted to the serving set.
    NodeUp {
        /// Node id.
        node: String,
    },
    /// Control plane: a node missed a heartbeat while in the serving
    /// set (early warning; eviction follows at the missed-beat
    /// threshold).
    NodeDown {
        /// Node id.
        node: String,
        /// Consecutive missed heartbeats so far.
        missed: u64,
    },
    /// Control plane: a node crossed the missed-beat threshold and was
    /// evicted from the serving set until it answers again.
    NodeEvict {
        /// Node id.
        node: String,
        /// Consecutive missed heartbeats at eviction.
        missed: u64,
    },
    /// A snapshot replication landed: the control plane pushed
    /// `route`@`version` to `node` and the node installed it (CRC
    /// verified). Emitted on both ends — route-scoped so it shows in
    /// the route's `stats events`.
    Replicate {
        /// Node the image was pushed to.
        node: String,
        /// Route the image belongs to.
        route: String,
        /// Registry version that was installed.
        version: u64,
    },
}

impl EventKind {
    /// Stable lowercase kind tag (journal lines, tests).
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::SnapshotSwap { .. } => "swap",
            EventKind::WorkerRestart { .. } => "worker_restart",
            EventKind::Quarantine { .. } => "quarantine",
            EventKind::RouteRecovered { .. } => "route_recovered",
            EventKind::RouteFailed { .. } => "route_failed",
            EventKind::ShedStart { .. } => "shed_start",
            EventKind::ShedEnd { .. } => "shed_end",
            EventKind::WatchReload { .. } => "watch_reload",
            EventKind::WatchFallback { .. } => "watch_fallback",
            EventKind::FeedbackPublish { .. } => "feedback_publish",
            EventKind::WalReplay { .. } => "wal_replay",
            EventKind::Drain { .. } => "drain",
            EventKind::NodeUp { .. } => "node_up",
            EventKind::NodeDown { .. } => "node_down",
            EventKind::NodeEvict { .. } => "node_evict",
            EventKind::Replicate { .. } => "replicate",
        }
    }

    /// The route this event concerns, if route-scoped.
    pub fn route(&self) -> Option<&str> {
        match self {
            EventKind::SnapshotSwap { route, .. }
            | EventKind::WorkerRestart { route, .. }
            | EventKind::Quarantine { route, .. }
            | EventKind::RouteRecovered { route, .. }
            | EventKind::RouteFailed { route, .. }
            | EventKind::ShedStart { route, .. }
            | EventKind::ShedEnd { route, .. }
            | EventKind::WatchReload { route, .. }
            | EventKind::WatchFallback { route, .. }
            | EventKind::FeedbackPublish { route, .. }
            | EventKind::WalReplay { route, .. }
            | EventKind::Replicate { route, .. } => Some(route),
            EventKind::Drain { .. }
            | EventKind::NodeUp { .. }
            | EventKind::NodeDown { .. }
            | EventKind::NodeEvict { .. } => None,
        }
    }

    /// Variant-specific `k=v` fields (route/kind excluded).
    fn detail(&self, out: &mut String) {
        use std::fmt::Write;
        match self {
            EventKind::SnapshotSwap {
                version, generation, ..
            } => {
                let _ = write!(out, " version={version} generation={generation}");
            }
            EventKind::WorkerRestart { restarts, .. } => {
                let _ = write!(out, " restarts={restarts}");
            }
            EventKind::Quarantine {
                version, reason, ..
            } => {
                let _ = write!(out, " version={version} reason={}", quote(reason));
            }
            EventKind::RouteRecovered { version, .. } => {
                let _ = write!(out, " version={version}");
            }
            EventKind::RouteFailed { error, .. } | EventKind::WatchFallback { error, .. } => {
                let _ = write!(out, " error={}", quote(error));
            }
            EventKind::ShedStart { trace, .. } => {
                let _ = write!(out, " trace={trace}");
            }
            EventKind::ShedEnd { shed_total, .. } => {
                let _ = write!(out, " shed_total={shed_total}");
            }
            EventKind::WatchReload { version, .. } => {
                let _ = write!(out, " version={version}");
            }
            EventKind::FeedbackPublish {
                version,
                generation,
                updates,
                ..
            } => {
                let _ = write!(
                    out,
                    " version={version} generation={generation} updates={updates}"
                );
            }
            EventKind::WalReplay {
                records,
                stale,
                skipped,
                ..
            } => {
                let _ = write!(out, " records={records} stale={stale} skipped={skipped}");
            }
            EventKind::Drain { reason } => {
                let _ = write!(out, " reason={}", quote(reason));
            }
            EventKind::NodeUp { node } => {
                let _ = write!(out, " node={node}");
            }
            EventKind::NodeDown { node, missed } | EventKind::NodeEvict { node, missed } => {
                let _ = write!(out, " node={node} missed={missed}");
            }
            EventKind::Replicate { node, version, .. } => {
                let _ = write!(out, " node={node} version={version}");
            }
        }
    }
}

/// Quote a free-form string for a single-line `k="v"` field: escapes
/// backslash and double quote, folds newlines — journal lines must
/// stay one line for the line protocol.
fn quote(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// One journal entry.
#[derive(Clone, Debug, PartialEq)]
pub struct Event {
    /// Monotonic sequence number (1-based, never reused; gaps mean
    /// nothing — eviction does not renumber).
    pub seq: u64,
    /// Wall clock, milliseconds since the UNIX epoch.
    pub wall_ms: u64,
    /// Monotonic microseconds since the journal was created.
    pub mono_us: u64,
    /// What happened (swap, restart, shed episode, …).
    pub kind: EventKind,
}

impl Event {
    /// Render as one `k=v` line (the `stats events` wire format).
    pub fn to_line(&self) -> String {
        let mut out = format!(
            "seq={} wall_ms={} mono_us={} kind={}",
            self.seq,
            self.wall_ms,
            self.mono_us,
            self.kind.name()
        );
        if let Some(route) = self.kind.route() {
            out.push_str(" route=");
            out.push_str(route);
        }
        self.kind.detail(&mut out);
        out
    }
}

struct Ring {
    events: VecDeque<Event>,
    next_seq: u64,
    dropped: u64,
}

/// Bounded, mutex-guarded event ring. Emission is rare (operational
/// events, not per-request), so a plain mutex is the right tool.
pub struct Journal {
    ring: Mutex<Ring>,
    capacity: usize,
    t0: Instant,
}

impl Journal {
    /// Ring journal retaining the most recent `capacity` events.
    pub fn new(capacity: usize) -> Self {
        Journal {
            ring: Mutex::new(Ring {
                events: VecDeque::with_capacity(capacity.min(64)),
                next_seq: 1,
                dropped: 0,
            }),
            capacity: capacity.max(1),
            t0: Instant::now(),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Ring> {
        self.ring.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Append an event, evicting the oldest when at capacity.
    pub fn emit(&self, kind: EventKind) {
        let wall_ms = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0);
        let mono_us = self.t0.elapsed().as_micros() as u64;
        let mut ring = self.lock();
        if ring.events.len() >= self.capacity {
            ring.events.pop_front();
            ring.dropped += 1;
        }
        let seq = ring.next_seq;
        ring.next_seq += 1;
        ring.events.push_back(Event {
            seq,
            wall_ms,
            mono_us,
            kind,
        });
    }

    /// Copy of every retained event, oldest first.
    pub fn snapshot(&self) -> Vec<Event> {
        self.lock().events.iter().cloned().collect()
    }

    /// Retained events concerning `route`, plus process-wide events
    /// (e.g. drain) — oldest first. This is `stats events <model>`.
    pub fn events_for(&self, route: &str) -> Vec<Event> {
        self.lock()
            .events
            .iter()
            .filter(|e| match e.kind.route() {
                Some(r) => r == route,
                None => true,
            })
            .cloned()
            .collect()
    }

    /// Retained event count.
    pub fn len(&self) -> usize {
        self.lock().events.len()
    }

    /// True if no events have been retained.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total events ever emitted (== the last seq handed out).
    pub fn emitted(&self) -> u64 {
        self.lock().next_seq - 1
    }

    /// Events evicted to honor the capacity bound.
    pub fn dropped(&self) -> u64 {
        self.lock().dropped
    }
}

/// The process-wide journal every subsystem emits into.
pub fn journal() -> &'static Journal {
    static JOURNAL: OnceLock<Journal> = OnceLock::new();
    JOURNAL.get_or_init(|| Journal::new(DEFAULT_CAPACITY))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emits_and_snapshots_in_order() {
        let j = Journal::new(8);
        j.emit(EventKind::SnapshotSwap {
            route: "cpu".into(),
            version: 2,
            generation: 5,
        });
        j.emit(EventKind::Drain {
            reason: "signal".into(),
        });
        let evs = j.snapshot();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].seq, 1);
        assert_eq!(evs[1].seq, 2);
        assert!(evs[1].mono_us >= evs[0].mono_us);
        assert_eq!(evs[0].kind.name(), "swap");
        assert_eq!(evs[0].kind.route(), Some("cpu"));
        assert_eq!(evs[1].kind.route(), None);
        assert_eq!(j.emitted(), 2);
        assert_eq!(j.dropped(), 0);
    }

    #[test]
    fn capacity_evicts_oldest_and_counts_drops() {
        let j = Journal::new(3);
        for i in 0..5u64 {
            j.emit(EventKind::WorkerRestart {
                route: "r".into(),
                restarts: i,
            });
        }
        let evs = j.snapshot();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].seq, 3, "oldest two evicted");
        assert_eq!(j.dropped(), 2);
        assert_eq!(j.emitted(), 5);
    }

    #[test]
    fn route_filter_includes_process_events() {
        let j = Journal::new(8);
        j.emit(EventKind::ShedStart {
            route: "a".into(),
            trace: 7,
        });
        j.emit(EventKind::ShedEnd {
            route: "b".into(),
            shed_total: 1,
        });
        j.emit(EventKind::Drain {
            reason: "test".into(),
        });
        let a = j.events_for("a");
        assert_eq!(a.len(), 2, "route a event + process-wide drain");
        assert_eq!(a[0].kind.name(), "shed_start");
        assert_eq!(a[1].kind.name(), "drain");
    }

    #[test]
    fn feedback_events_render_their_fields() {
        let j = Journal::new(4);
        j.emit(EventKind::FeedbackPublish {
            route: "cpu".into(),
            version: 3,
            generation: 7,
            updates: 64,
        });
        j.emit(EventKind::WalReplay {
            route: "cpu".into(),
            records: 12,
            stale: 3,
            skipped: 1,
        });
        let evs = j.snapshot();
        assert_eq!(evs[0].kind.name(), "feedback_publish");
        assert_eq!(evs[0].kind.route(), Some("cpu"));
        assert!(evs[0]
            .to_line()
            .contains("kind=feedback_publish route=cpu version=3 generation=7 updates=64"));
        assert_eq!(evs[1].kind.name(), "wal_replay");
        assert!(evs[1]
            .to_line()
            .contains("kind=wal_replay route=cpu records=12 stale=3 skipped=1"));
    }

    #[test]
    fn cluster_events_render_their_fields() {
        let j = Journal::new(8);
        j.emit(EventKind::NodeUp { node: "n1".into() });
        j.emit(EventKind::NodeDown {
            node: "n1".into(),
            missed: 1,
        });
        j.emit(EventKind::NodeEvict {
            node: "n1".into(),
            missed: 3,
        });
        j.emit(EventKind::Replicate {
            node: "n2".into(),
            route: "cpu".into(),
            version: 4,
        });
        let evs = j.snapshot();
        assert!(evs[0].to_line().contains("kind=node_up node=n1"));
        assert!(evs[1].to_line().contains("kind=node_down node=n1 missed=1"));
        assert!(evs[2].to_line().contains("kind=node_evict node=n1 missed=3"));
        // node liveness is process-wide; replication is route-scoped
        assert_eq!(evs[2].kind.route(), None);
        assert_eq!(evs[3].kind.route(), Some("cpu"));
        assert!(evs[3]
            .to_line()
            .contains("kind=replicate route=cpu node=n2 version=4"));
    }

    #[test]
    fn line_format_escapes_free_text() {
        let j = Journal::new(4);
        j.emit(EventKind::Quarantine {
            route: "cpu".into(),
            version: 3,
            reason: "bad \"crc\"\nline".into(),
        });
        let line = j.snapshot()[0].to_line();
        assert!(line.starts_with("seq=1 wall_ms="));
        assert!(line.contains(" kind=quarantine route=cpu version=3 reason="));
        assert!(
            !line.contains('\n'),
            "journal lines must stay single-line: {line:?}"
        );
        assert!(line.contains("\\\"crc\\\"\\nline"));
    }
}
