//! Reusable lock-free power-of-two histogram.
//!
//! Generalizes the serving `Metrics` latency histogram into a type any
//! subsystem can embed: 24 buckets whose upper bounds are `2^(i+1)`
//! units (microseconds everywhere in this repo: 1us .. ~8.4s), one
//! relaxed `fetch_add` per record plus a running sum so Prometheus
//! exposition can emit `_sum`/`_count` alongside `_bucket`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Bucket count: upper bounds `2, 4, 8, .., 2^24` (~16.7s); the last
/// bucket additionally absorbs every larger value.
pub const BUCKETS: usize = 24;

/// Lock-free fixed-bucket histogram (power-of-two upper bounds).
#[derive(Debug, Default)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    sum: AtomicU64,
}

impl Histogram {
    /// Empty histogram; buckets are powers of two up to `u64::MAX`.
    pub fn new() -> Self {
        Self::default()
    }

    /// Bucket index for `value`: floor(log2(max(value,1))), clamped.
    #[inline]
    pub fn bucket_index(value: u64) -> usize {
        (64 - value.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1)
    }

    /// Upper bound of bucket `i` (`2^(i+1)`).
    #[inline]
    pub fn bucket_bound(i: usize) -> u64 {
        1u64 << (i + 1)
    }

    /// Record one observation (relaxed; safe from any thread).
    #[inline]
    pub fn record(&self, value: u64) {
        self.buckets[Self::bucket_index(value)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(value, Ordering::Relaxed);
    }

    /// Record a duration in microseconds.
    #[inline]
    pub fn record_duration(&self, d: Duration) {
        self.record(d.as_micros() as u64);
    }

    /// Point-in-time copy. Bucket counts and the sum are read with
    /// relaxed loads, so under concurrent writers the sum may lag the
    /// buckets by in-flight observations — each read value is still a
    /// real past value (no torn u64 reads).
    pub fn snapshot(&self) -> HistogramSnapshot {
        let mut buckets = Vec::new();
        let mut count = 0u64;
        for (i, c) in self.buckets.iter().enumerate() {
            let n = c.load(Ordering::Relaxed);
            count += n;
            if n > 0 {
                buckets.push((Self::bucket_bound(i), n));
            }
        }
        HistogramSnapshot {
            buckets,
            count,
            sum: self.sum.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time histogram copy for reporting.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HistogramSnapshot {
    /// `(upper_bound, count)` for non-empty buckets, ascending bounds.
    pub buckets: Vec<(u64, u64)>,
    /// Total observations.
    pub count: u64,
    /// Sum of observed values (same unit as the bounds).
    pub sum: u64,
}

impl HistogramSnapshot {
    /// Approximate quantile: the upper bound of the bucket holding the
    /// nearest-rank observation. `None` when empty.
    pub fn quantile(&self, q: f64) -> Option<u64> {
        if self.count == 0 {
            return None;
        }
        let target = ((self.count as f64) * q).ceil() as u64;
        let mut seen = 0;
        for &(bound, count) in &self.buckets {
            seen += count;
            if seen >= target {
                return Some(bound);
            }
        }
        self.buckets.last().map(|&(b, _)| b)
    }

    /// p50 (0 when empty) — stats-line formatting convenience.
    pub fn p50(&self) -> u64 {
        self.quantile(0.5).unwrap_or(0)
    }

    /// p95 (0 when empty).
    pub fn p95(&self) -> u64 {
        self.quantile(0.95).unwrap_or(0)
    }

    /// p99 (0 when empty).
    pub fn p99(&self) -> u64 {
        self.quantile(0.99).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries() {
        assert_eq!(Histogram::bucket_index(0), 0);
        assert_eq!(Histogram::bucket_index(1), 0);
        assert_eq!(Histogram::bucket_index(2), 1);
        assert_eq!(Histogram::bucket_index(3), 1);
        assert_eq!(Histogram::bucket_index(1024), 10);
        assert_eq!(Histogram::bucket_index(u64::MAX), BUCKETS - 1);
        assert_eq!(Histogram::bucket_bound(0), 2);
        assert_eq!(Histogram::bucket_bound(10), 2048);
    }

    #[test]
    fn record_and_quantiles() {
        let h = Histogram::new();
        h.record(100);
        h.record(90);
        h.record_duration(Duration::from_millis(10));
        let s = h.snapshot();
        assert_eq!(s.count, 3);
        assert_eq!(s.sum, 100 + 90 + 10_000);
        // 2 fast + 1 slow: p50 lands in the ~128us bucket
        assert_eq!(s.quantile(0.5), Some(128));
        assert!(s.quantile(0.99).unwrap() >= 8192);
        assert_eq!(s.p50(), 128);
        assert!(s.p95() >= 8192 && s.p99() >= s.p95());
    }

    #[test]
    fn empty_snapshot() {
        let s = Histogram::new().snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.quantile(0.5), None);
        assert_eq!((s.p50(), s.p95(), s.p99()), (0, 0, 0));
    }

    #[test]
    fn oversized_values_clamp_to_last_bucket() {
        let h = Histogram::new();
        h.record(u64::MAX);
        let s = h.snapshot();
        assert_eq!(s.buckets, vec![(Histogram::bucket_bound(BUCKETS - 1), 1)]);
        assert_eq!(s.quantile(1.0), Some(Histogram::bucket_bound(BUCKETS - 1)));
    }
}
