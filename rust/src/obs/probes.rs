//! Index-efficiency probes: how much work the paper's clause index
//! actually avoids on live traffic.
//!
//! Two tiers keep the hot loops honest:
//!
//! * **Scratch tier** ([`ProbeDelta`]): plain (non-atomic) `u64`
//!   counters embedded in the engines' per-thread scratch. The fused
//!   walk and the sparse-delta walk bump them with ordinary adds —
//!   zero synchronization in the per-clause loops. Workers flush the
//!   accumulated delta into the route's relaxed-atomic `Metrics` once
//!   per batch.
//! * **Process tier**: relaxed-atomic statics for the training-side
//!   feedback path (`tm/feedback.rs`), where there is no per-route
//!   home — include/exclude flips forwarded to the index maintenance
//!   sinks, and clause updates sampled. One `fetch_add` per
//!   clause-range update, not per flip.

use std::sync::atomic::{AtomicU64, Ordering};

/// Non-atomic probe accumulator carried inside engine scratch.
///
/// `clauses_falsified` counts unique clauses the index walk knocked
/// out (the only per-clause work an indexed evaluation performs);
/// `clauses_skipped` counts clause evaluations avoided outright —
/// clauses a naive evaluator would have walked literal-by-literal but
/// the index never touched. Their ratio is the serving-time face of
/// the paper's speedup claim.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ProbeDelta {
    /// Samples scored by the dense fused falsification walk.
    pub dense_samples: u64,
    /// Samples scored by the O(nnz) sparse-delta walk.
    pub sparse_samples: u64,
    /// Unique clauses falsified via the index (dedup-stamped).
    pub clauses_falsified: u64,
    /// Clause evaluations skipped entirely (total clauses − falsified).
    pub clauses_skipped: u64,
    /// False non-empty literals walked by the dense engine.
    pub features_walked: u64,
    /// Per-literal delta-row toggles applied by the sparse engine.
    pub sparse_toggles: u64,
}

impl ProbeDelta {
    /// Take the accumulated delta, leaving zeros behind (batch flush).
    pub fn take(&mut self) -> ProbeDelta {
        std::mem::take(self)
    }

    /// Field-wise add (merging a sibling scratch's delta).
    pub fn merge(&mut self, other: &ProbeDelta) {
        self.dense_samples += other.dense_samples;
        self.sparse_samples += other.sparse_samples;
        self.clauses_falsified += other.clauses_falsified;
        self.clauses_skipped += other.clauses_skipped;
        self.features_walked += other.features_walked;
        self.sparse_toggles += other.sparse_toggles;
    }

    /// True when nothing has been recorded since the last take.
    pub fn is_empty(&self) -> bool {
        *self == ProbeDelta::default()
    }

    /// Fraction of clause evaluations the index avoided (0 when no
    /// samples have been scored).
    pub fn index_efficiency(&self) -> f64 {
        index_efficiency(self.clauses_falsified, self.clauses_skipped)
    }
}

/// `skipped / (skipped + falsified)`, or 0 with no data.
pub fn index_efficiency(falsified: u64, skipped: u64) -> f64 {
    let total = falsified + skipped;
    if total == 0 {
        0.0
    } else {
        skipped as f64 / total as f64
    }
}

/// Include/exclude flips forwarded to index-maintenance sinks by the
/// feedback path (process-wide; training-side).
pub static FEEDBACK_FLIPS: AtomicU64 = AtomicU64::new(0);

/// Clause updates sampled by `update_clause_range` (process-wide).
pub static FEEDBACK_CLAUSE_UPDATES: AtomicU64 = AtomicU64::new(0);

/// Current process-wide feedback flip count.
pub fn feedback_flips() -> u64 {
    FEEDBACK_FLIPS.load(Ordering::Relaxed)
}

/// Current process-wide feedback clause-update count.
pub fn feedback_clause_updates() -> u64 {
    FEEDBACK_CLAUSE_UPDATES.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_drains_and_merge_adds() {
        let mut a = ProbeDelta {
            dense_samples: 2,
            clauses_falsified: 10,
            clauses_skipped: 90,
            features_walked: 40,
            ..ProbeDelta::default()
        };
        let mut b = ProbeDelta {
            sparse_samples: 1,
            sparse_toggles: 7,
            clauses_falsified: 5,
            clauses_skipped: 15,
            ..ProbeDelta::default()
        };
        b.merge(&a.take());
        assert!(a.is_empty());
        assert_eq!(b.dense_samples, 2);
        assert_eq!(b.sparse_samples, 1);
        assert_eq!(b.clauses_falsified, 15);
        assert_eq!(b.clauses_skipped, 105);
        assert_eq!(b.features_walked, 40);
        assert_eq!(b.sparse_toggles, 7);
    }

    #[test]
    fn efficiency_ratio() {
        assert_eq!(index_efficiency(0, 0), 0.0);
        assert!((index_efficiency(10, 90) - 0.9).abs() < 1e-12);
        let d = ProbeDelta {
            clauses_falsified: 1,
            clauses_skipped: 3,
            ..ProbeDelta::default()
        };
        assert!((d.index_efficiency() - 0.75).abs() < 1e-12);
    }
}
