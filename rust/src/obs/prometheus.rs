//! Hand-rolled Prometheus text exposition (format 0.0.4) — no crates.
//!
//! [`PromWriter`] renders counters, gauges, and histograms with the
//! escaping rules of the text format; [`validate_exposition`] is the
//! conformance checker the tests and the `tmi promcheck` CLI run over
//! real scrape output (metric/label name charsets, `# HELP`/`# TYPE`
//! discipline, histogram `_bucket` cumulativity and `_sum`/`_count`
//! presence).

use super::histogram::HistogramSnapshot;
use std::collections::{BTreeMap, BTreeSet};
use std::fmt::Write as _;

/// `[a-zA-Z_:][a-zA-Z0-9_:]*`
pub fn is_valid_metric_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

/// `[a-zA-Z_][a-zA-Z0-9_]*`
pub fn is_valid_label_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_')
}

/// Escape a label value: `\\`, `\"`, `\n`.
pub fn escape_label_value(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Escape HELP text: `\\` and `\n` (quotes are legal there).
pub fn escape_help(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Streaming exposition builder. Families are written header-first
/// (`# HELP`, `# TYPE`), then any number of samples; [`finish`]
/// terminates with `# EOF` (OpenMetrics-style trailer, a plain
/// comment under 0.0.4 — clients reading the `metrics` protocol verb
/// use it as the end-of-reply marker).
///
/// [`finish`]: PromWriter::finish
#[derive(Debug, Default)]
pub struct PromWriter {
    out: String,
}

impl PromWriter {
    /// Fresh writer with an empty exposition buffer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Start a metric family. `kind` is `counter`, `gauge`, or
    /// `histogram`. Invalid names are a programming error.
    pub fn header(&mut self, name: &str, help: &str, kind: &str) {
        debug_assert!(is_valid_metric_name(name), "bad metric name {name:?}");
        let _ = writeln!(self.out, "# HELP {name} {}", escape_help(help));
        let _ = writeln!(self.out, "# TYPE {name} {kind}");
    }

    fn write_labels(&mut self, labels: &[(&str, &str)]) {
        if labels.is_empty() {
            return;
        }
        self.out.push('{');
        for (i, (k, v)) in labels.iter().enumerate() {
            debug_assert!(is_valid_label_name(k), "bad label name {k:?}");
            if i > 0 {
                self.out.push(',');
            }
            let _ = write!(self.out, "{k}=\"{}\"", escape_label_value(v));
        }
        self.out.push('}');
    }

    /// One float sample. (Rust's `Display` for `f64` prints integral
    /// values without a trailing `.0`, which the format accepts.)
    pub fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        debug_assert!(is_valid_metric_name(name), "bad metric name {name:?}");
        self.out.push_str(name);
        self.write_labels(labels);
        let _ = writeln!(self.out, " {value}");
    }

    /// One integer sample (counters/gauges; exact at any magnitude).
    pub fn int_sample(&mut self, name: &str, labels: &[(&str, &str)], value: u64) {
        debug_assert!(is_valid_metric_name(name), "bad metric name {name:?}");
        self.out.push_str(name);
        self.write_labels(labels);
        let _ = writeln!(self.out, " {value}");
    }

    /// Emit `_bucket` (cumulative, with `le="+Inf"`), `_sum`, and
    /// `_count` series for one histogram under `labels`.
    pub fn histogram(&mut self, name: &str, labels: &[(&str, &str)], h: &HistogramSnapshot) {
        let bucket_name = format!("{name}_bucket");
        let mut cumulative = 0u64;
        for &(bound, count) in &h.buckets {
            cumulative += count;
            let le = bound.to_string();
            let mut with_le: Vec<(&str, &str)> = Vec::with_capacity(labels.len() + 1);
            with_le.extend_from_slice(labels);
            with_le.push(("le", &le));
            self.int_sample(&bucket_name, &with_le, cumulative);
        }
        let mut with_le: Vec<(&str, &str)> = Vec::with_capacity(labels.len() + 1);
        with_le.extend_from_slice(labels);
        with_le.push(("le", "+Inf"));
        self.int_sample(&bucket_name, &with_le, h.count);
        self.int_sample(&format!("{name}_sum"), labels, h.sum);
        self.int_sample(&format!("{name}_count"), labels, h.count);
    }

    /// Terminate and take the exposition text.
    pub fn finish(mut self) -> String {
        self.out.push_str("# EOF\n");
        self.out
    }
}

// ---------------------------------------------------------------------------
// Conformance validator
// ---------------------------------------------------------------------------

#[derive(Debug, Default)]
struct Families {
    /// family name -> declared TYPE
    types: BTreeMap<String, String>,
    /// family names with a HELP line
    helped: BTreeSet<String>,
    /// families that have emitted at least one sample
    sampled: BTreeSet<String>,
    /// full sample identity (name + serialized labels) seen so far
    seen: BTreeSet<String>,
    /// histogram family -> labelset(minus le) -> series values
    histograms: BTreeMap<String, BTreeMap<String, HistogramSeries>>,
}

#[derive(Debug, Default)]
struct HistogramSeries {
    /// (le, cumulative count) pairs in order of appearance
    buckets: Vec<(f64, f64)>,
    sum: Option<f64>,
    count: Option<f64>,
}

/// Strict conformance check for an exposition produced by this crate:
/// every sample must belong to a family with exactly one `# TYPE` and
/// a `# HELP` that precede it, names and label names must match the
/// format charsets, no duplicate series, and histogram `_bucket`
/// series must be cumulative with `le="+Inf"` equal to `_count` and a
/// `_sum` present. Returns the first violation.
pub fn validate_exposition(text: &str) -> Result<(), String> {
    let mut fam = Families::default();
    for (lineno, raw) in text.lines().enumerate() {
        let n = lineno + 1;
        let line = raw.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest.split_whitespace().next().unwrap_or("");
            if !is_valid_metric_name(name) {
                return Err(format!("line {n}: bad HELP metric name {name:?}"));
            }
            if !fam.helped.insert(name.to_string()) {
                return Err(format!("line {n}: duplicate HELP for {name}"));
            }
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().unwrap_or("");
            let kind = it.next().unwrap_or("");
            if !is_valid_metric_name(name) {
                return Err(format!("line {n}: bad TYPE metric name {name:?}"));
            }
            if !matches!(kind, "counter" | "gauge" | "histogram" | "summary" | "untyped") {
                return Err(format!("line {n}: unknown TYPE {kind:?} for {name}"));
            }
            if fam.sampled.contains(name) {
                return Err(format!("line {n}: TYPE for {name} after its samples"));
            }
            if fam.types.insert(name.to_string(), kind.to_string()).is_some() {
                return Err(format!("line {n}: duplicate TYPE for {name}"));
            }
            continue;
        }
        if line.starts_with('#') {
            continue; // plain comment (includes the # EOF trailer)
        }
        parse_sample(line, n, &mut fam)?;
    }
    for name in &fam.sampled {
        let family = histogram_family(name, &fam.types).unwrap_or_else(|| name.clone());
        if !fam.types.contains_key(&family) {
            return Err(format!("samples for {name} have no # TYPE"));
        }
        if !fam.helped.contains(&family) {
            return Err(format!("samples for {name} have no # HELP"));
        }
    }
    for (family, by_labels) in &fam.histograms {
        for (labels, series) in by_labels {
            check_histogram_series(family, labels, series)?;
        }
    }
    Ok(())
}

/// If `name` is a `_bucket`/`_sum`/`_count` series of a declared
/// histogram family, return that family name.
fn histogram_family(name: &str, types: &BTreeMap<String, String>) -> Option<String> {
    for suffix in ["_bucket", "_sum", "_count"] {
        if let Some(base) = name.strip_suffix(suffix) {
            if types.get(base).map(String::as_str) == Some("histogram") {
                return Some(base.to_string());
            }
        }
    }
    None
}

fn parse_sample(line: &str, n: usize, fam: &mut Families) -> Result<(), String> {
    // metric name
    let name_end = line
        .find(|c: char| c == '{' || c == ' ')
        .ok_or_else(|| format!("line {n}: no value in sample {line:?}"))?;
    let name = &line[..name_end];
    if !is_valid_metric_name(name) {
        return Err(format!("line {n}: bad sample metric name {name:?}"));
    }
    // labels
    let mut labels: Vec<(String, String)> = Vec::new();
    let rest = if line[name_end..].starts_with('{') {
        let body_end = line[name_end..]
            .find('}')
            .map(|i| name_end + i)
            .ok_or_else(|| format!("line {n}: unterminated label set"))?;
        parse_labels(&line[name_end + 1..body_end], n, &mut labels)?;
        &line[body_end + 1..]
    } else {
        &line[name_end..]
    };
    // value (timestamps not used by this crate)
    let value_str = rest.trim();
    let value: f64 = match value_str {
        "+Inf" => f64::INFINITY,
        "-Inf" => f64::NEG_INFINITY,
        v => v
            .split_whitespace()
            .next()
            .unwrap_or("")
            .parse()
            .map_err(|_| format!("line {n}: bad sample value {value_str:?}"))?,
    };
    // duplicate series detection
    let mut identity = name.to_string();
    let mut sorted = labels.clone();
    sorted.sort();
    for (k, v) in &sorted {
        identity.push('\u{1}');
        identity.push_str(k);
        identity.push('\u{2}');
        identity.push_str(v);
    }
    if !fam.seen.insert(identity) {
        return Err(format!("line {n}: duplicate series {line:?}"));
    }
    fam.sampled.insert(name.to_string());
    // histogram bookkeeping
    if let Some(family) = histogram_family(name, &fam.types) {
        let le = labels.iter().find(|(k, _)| k == "le").map(|(_, v)| v.clone());
        let mut key_labels: Vec<(String, String)> = sorted
            .iter()
            .filter(|(k, _)| k != "le")
            .cloned()
            .collect();
        let key = {
            let mut s = String::new();
            for (k, v) in key_labels.drain(..) {
                s.push('\u{1}');
                s.push_str(&k);
                s.push('\u{2}');
                s.push_str(&v);
            }
            s
        };
        let series = fam
            .histograms
            .entry(family)
            .or_default()
            .entry(key)
            .or_default();
        if name.ends_with("_bucket") {
            let le = le.ok_or_else(|| format!("line {n}: _bucket sample without le label"))?;
            let le_val = match le.as_str() {
                "+Inf" => f64::INFINITY,
                v => v
                    .parse()
                    .map_err(|_| format!("line {n}: bad le value {le:?}"))?,
            };
            series.buckets.push((le_val, value));
        } else if name.ends_with("_sum") {
            series.sum = Some(value);
        } else {
            series.count = Some(value);
        }
    }
    Ok(())
}

fn parse_labels(
    body: &str,
    n: usize,
    out: &mut Vec<(String, String)>,
) -> Result<(), String> {
    let mut chars = body.chars().peekable();
    loop {
        // label name
        let mut name = String::new();
        while let Some(&c) = chars.peek() {
            if c == '=' {
                break;
            }
            name.push(c);
            chars.next();
        }
        let name = name.trim().to_string();
        if !is_valid_label_name(&name) {
            return Err(format!("line {n}: bad label name {name:?}"));
        }
        if chars.next() != Some('=') || chars.next() != Some('"') {
            return Err(format!("line {n}: label {name} not in k=\"v\" form"));
        }
        // quoted value with escapes
        let mut value = String::new();
        loop {
            match chars.next() {
                Some('\\') => match chars.next() {
                    Some('\\') => value.push('\\'),
                    Some('"') => value.push('"'),
                    Some('n') => value.push('\n'),
                    other => {
                        return Err(format!("line {n}: bad escape {other:?} in label {name}"))
                    }
                },
                Some('"') => break,
                Some(c) => value.push(c),
                None => return Err(format!("line {n}: unterminated label value for {name}")),
            }
        }
        out.push((name, value));
        match chars.next() {
            Some(',') => continue,
            None => return Ok(()),
            Some(c) => return Err(format!("line {n}: unexpected {c:?} after label")),
        }
    }
}

fn check_histogram_series(
    family: &str,
    labels: &str,
    series: &HistogramSeries,
) -> Result<(), String> {
    let ctx = if labels.is_empty() {
        family.to_string()
    } else {
        format!("{family}{{{}}}", labels.replace('\u{1}', " ").replace('\u{2}', "="))
    };
    let count = series
        .count
        .ok_or_else(|| format!("histogram {ctx}: missing _count"))?;
    if series.sum.is_none() {
        return Err(format!("histogram {ctx}: missing _sum"));
    }
    let mut buckets = series.buckets.clone();
    if buckets.is_empty() {
        return Err(format!("histogram {ctx}: no _bucket series"));
    }
    buckets.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut prev = -1.0f64;
    for &(le, v) in &buckets {
        if v < prev {
            return Err(format!(
                "histogram {ctx}: bucket le={le} count {v} < previous {prev} (not cumulative)"
            ));
        }
        prev = v;
    }
    let (last_le, last_v) = *buckets.last().unwrap();
    if !last_le.is_infinite() {
        return Err(format!("histogram {ctx}: missing le=\"+Inf\" bucket"));
    }
    if (last_v - count).abs() > 0.0 {
        return Err(format!(
            "histogram {ctx}: le=+Inf bucket {last_v} != _count {count}"
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn name_charsets() {
        assert!(is_valid_metric_name("tmi_requests_total"));
        assert!(is_valid_metric_name("a:b_c1"));
        assert!(!is_valid_metric_name("1abc"));
        assert!(!is_valid_metric_name("a-b"));
        assert!(!is_valid_metric_name(""));
        assert!(is_valid_label_name("route"));
        assert!(!is_valid_label_name("le:x"));
        assert!(!is_valid_label_name("9x"));
    }

    #[test]
    fn escaping() {
        assert_eq!(escape_label_value("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(escape_help("x\\y\nz"), "x\\\\y\\nz");
    }

    #[test]
    fn writer_roundtrips_through_validator() {
        let mut w = PromWriter::new();
        w.header("tmi_requests_total", "Total admitted requests.", "counter");
        w.int_sample("tmi_requests_total", &[("route", "cpu")], 42);
        w.int_sample("tmi_requests_total", &[("route", "a\"b")], 7);
        w.header("tmi_queue_depth", "Live queue depth.", "gauge");
        w.sample("tmi_queue_depth", &[("route", "cpu")], 3.0);
        w.header("tmi_latency_us", "Request latency.", "histogram");
        let h = HistogramSnapshot {
            buckets: vec![(2, 1), (8, 3)],
            count: 4,
            sum: 20,
        };
        w.histogram("tmi_latency_us", &[("route", "cpu")], &h);
        let text = w.finish();
        assert!(text.ends_with("# EOF\n"));
        assert!(text.contains("tmi_latency_us_bucket{route=\"cpu\",le=\"8\"} 4"));
        assert!(text.contains("tmi_latency_us_bucket{route=\"cpu\",le=\"+Inf\"} 4"));
        assert!(text.contains("tmi_latency_us_sum{route=\"cpu\"} 20"));
        validate_exposition(&text).unwrap();
    }

    #[test]
    fn validator_rejects_violations() {
        // sample without TYPE
        assert!(validate_exposition("loose_metric 1\n").is_err());
        // TYPE after sample
        let bad = "# HELP m h\nm 1\n# TYPE m counter\n";
        assert!(validate_exposition(bad).is_err());
        // duplicate series
        let dup = "# HELP m h\n# TYPE m counter\nm{a=\"1\"} 1\nm{a=\"1\"} 2\n";
        assert!(validate_exposition(dup).is_err());
        // non-cumulative histogram
        let noncum = "# HELP h h\n# TYPE h histogram\n\
                      h_bucket{le=\"1\"} 5\nh_bucket{le=\"2\"} 3\n\
                      h_bucket{le=\"+Inf\"} 5\nh_sum 9\nh_count 5\n";
        assert!(validate_exposition(noncum).unwrap_err().contains("not cumulative"));
        // +Inf != count
        let inf = "# HELP h h\n# TYPE h histogram\n\
                   h_bucket{le=\"+Inf\"} 4\nh_sum 9\nh_count 5\n";
        assert!(validate_exposition(inf).is_err());
        // missing _sum
        let nosum = "# HELP h h\n# TYPE h histogram\n\
                     h_bucket{le=\"+Inf\"} 5\nh_count 5\n";
        assert!(validate_exposition(nosum).unwrap_err().contains("_sum"));
        // bad metric name
        assert!(validate_exposition("# HELP 1bad h\n").is_err());
    }

    #[test]
    fn validator_accepts_label_edge_cases() {
        let text = "# HELP m h\n# TYPE m gauge\nm{v=\"a\\\\b\\\"c\\nd\"} 1.5\nm 2\n# EOF\n";
        validate_exposition(text).unwrap();
    }
}
