//! Dependency-free observability: per-stage request tracing,
//! index-efficiency probes, Prometheus text exposition, and a bounded
//! structured event journal.
//!
//! The paper's value claim is *work avoided* — clauses eliminated by
//! the falsification look-up table instead of evaluated. This module
//! makes that visible on live traffic:
//!
//! * [`histogram`] — [`Histogram`]: the reusable power-of-two
//!   microsecond histogram behind every latency metric (generalized
//!   from the old `Metrics` latency histogram).
//! * [`probes`] — [`ProbeDelta`]: non-atomic per-scratch counters the
//!   engines bump in their hot loops (clauses falsified by the index
//!   vs clause evaluations skipped outright, features walked,
//!   sparse-delta toggles), flushed batch-wise into the route's
//!   relaxed-atomic `Metrics`; plus process-wide feedback-flip
//!   counters maintained by `tm/feedback.rs`.
//! * [`prometheus`] — hand-rolled Prometheus text format 0.0.4
//!   rendering and a conformance validator (no crates).
//! * [`journal`] — a bounded ring of typed operational events
//!   (snapshot swap, worker restart, quarantine, shed episodes,
//!   drain) with monotonic + wall timestamps.
//!
//! Everything is on by default; [`set_enabled`]`(false)` (CLI:
//! `tmi serve --obs off`) drops the per-request stage clocking so the
//! CI overhead gate can measure instrumented-vs-bare throughput.

pub mod histogram;
pub mod journal;
pub mod probes;
pub mod prometheus;

pub use histogram::{Histogram, HistogramSnapshot};
pub use journal::{journal, Event, EventKind, Journal};
pub use probes::ProbeDelta;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// Request pipeline stages clocked by the serving coordinator.
///
/// Stage semantics (all microseconds, power-of-two buckets):
///
/// * `Queue` — admission to batch-ready: time the request sat in the
///   bounded queue plus the assembly wait of the batch that carried it.
/// * `Batch` — per batch: first pop to batch-ready (size/deadline
///   collection window of [`crate::coordinator::BatchPolicy`]).
/// * `Score` — per request: the engine scoring call alone.
/// * `Write` — the TCP reply write observed by the connection thread
///   (spikes when the client stops reading).
/// * `Feedback` — per labeled example: the online learner's WAL append
///   plus the `Trainer::train_sample` update (learn-while-serving).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Admission to dequeue.
    Queue = 0,
    /// Batch assembly.
    Batch = 1,
    /// Engine scoring.
    Score = 2,
    /// Reply bytes onto the socket.
    Write = 3,
    /// Online-learning feedback application.
    Feedback = 4,
}

/// Number of [`Stage`] variants (array sizing).
pub const STAGES: usize = 5;

impl Stage {
    /// All pipeline stages, in request order.
    pub const ALL: [Stage; STAGES] = [
        Stage::Queue,
        Stage::Batch,
        Stage::Score,
        Stage::Write,
        Stage::Feedback,
    ];

    /// Stable lowercase name (stats keys, Prometheus `stage` label).
    pub fn name(self) -> &'static str {
        match self {
            Stage::Queue => "queue",
            Stage::Batch => "batch",
            Stage::Score => "score",
            Stage::Write => "write",
            Stage::Feedback => "feedback",
        }
    }
}

static ENABLED: AtomicBool = AtomicBool::new(true);
static NEXT_TRACE: AtomicU64 = AtomicU64::new(1);

/// Is per-request stage clocking enabled? (Probe deltas and the
/// journal stay on either way — they are branch-free or rare.)
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Toggle per-request stage clocking (process-wide; `serve --obs off`).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Allocate the next process-wide trace id (1-based, never reused).
#[inline]
pub fn next_trace_id() -> u64 {
    NEXT_TRACE.fetch_add(1, Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_names_and_order() {
        assert_eq!(Stage::ALL.len(), STAGES);
        for (i, s) in Stage::ALL.iter().enumerate() {
            assert_eq!(*s as usize, i);
        }
        assert_eq!(Stage::Queue.name(), "queue");
        assert_eq!(Stage::Write.name(), "write");
        assert_eq!(Stage::Feedback.name(), "feedback");
    }

    #[test]
    fn trace_ids_are_unique_and_increasing() {
        let a = next_trace_id();
        let b = next_trace_id();
        assert!(b > a);
        assert!(a >= 1);
    }
}
