//! The durable side of serving: an on-disk, versioned snapshot store.
//!
//! Layout under the registry root (`tmi serve --registry <dir>`):
//!
//! ```text
//! <dir>/manifest.json         current route table (atomically rewritten)
//! <dir>/manifest.json.bak     previous generation (crash fallback)
//! <dir>/<route>/v000001.tm    checksummed v3 model files, one per version
//! <dir>/<route>/feedback.wal  CRC-framed online-feedback log ([`wal`])
//! <dir>/quarantine/           torn/corrupt files moved aside, never served
//! ```
//!
//! The manifest is the single source of truth: route name, infer mode,
//! published version, and the CRC-32 digest + byte length of every
//! retained model file. A restarted server rebuilds its whole route
//! table from the manifest alone ([`Registry::open`] +
//! [`Registry::load_published`]); any file whose digest no longer
//! matches — truncated by a crashed writer, bit-flipped by the fault
//! harness — is *quarantined* (moved to `quarantine/`, dropped from the
//! manifest) and recovery falls back to the newest intact version
//! instead of panicking.
//!
//! Writes are crash-ordered throughout: model files and the manifest
//! are written to a `.tmp` sibling, fsynced, then renamed into place,
//! and the previous manifest generation is kept as `.bak` so a torn
//! manifest rewrite degrades to the last good route table.
//!
//! [`watch`] replaces the old mtime/length file poll for `--watch`
//! mode: pollers compare the manifest *generation* (bumped on every
//! mutation), so a same-mtime same-length rewrite can never be missed.

pub mod manifest;
pub mod store;
pub mod wal;
pub mod watch;

pub use manifest::{Manifest, RouteEntry, VersionEntry};
pub use store::{GcReport, RecoveredModel, Registry, RegistryError, VerifyIssue};
pub use wal::{FeedbackRecord, FeedbackWal, WalReplay};
pub use watch::{read_generation, sync_published, SyncEvent, WatchState};
