//! Manifest-generation polling for `tmi serve --registry --watch`.
//!
//! The old file watch compared `(mtime, len)` of the model file — a
//! rewrite that lands within the filesystem's mtime granularity with
//! the same byte length is invisible to it. The registry watch compares
//! the manifest **generation**, a counter bumped on every registry
//! mutation, so no rewrite can ever be missed; and because recovery
//! runs through [`Registry::load_published`], a corrupt file published
//! mid-watch is quarantined while the route keeps serving its current
//! snapshot.

use std::collections::BTreeMap;
use std::path::Path;

use crate::obs::{journal, EventKind};
use crate::registry::manifest::Manifest;
use crate::registry::store::{RecoveredModel, Registry};

/// Cheap poll: the current manifest generation, or `None` when no
/// readable manifest exists (including mid-rewrite with no backup —
/// the poller just tries again).
pub fn read_generation(dir: &Path) -> Option<u64> {
    Manifest::load(dir).ok().map(|l| l.manifest.generation)
}

/// Poller state: the generation last acted on and the version currently
/// served per route.
#[derive(Clone, Debug, Default)]
pub struct WatchState {
    /// Manifest generation last acted on.
    pub generation: u64,
    /// Published version currently being served, per route.
    pub served: BTreeMap<String, u64>,
}

/// What one [`sync_published`] pass did for one route.
#[derive(Debug)]
pub enum SyncEvent {
    /// A newer intact version was recovered and handed to `apply`.
    Published {
        /// Route that was recovered.
        route: String,
        /// Version now being served.
        version: u64,
        /// Versions quarantined on the way to the intact one.
        quarantined: Vec<u64>,
    },
    /// Recovery (or the caller's `apply`) failed; the route keeps
    /// serving whatever it served before.
    Failed {
        /// Route whose recovery failed.
        route: String,
        /// Human-readable failure.
        error: String,
    },
}

/// Reconcile served versions with the registry: for every route whose
/// published version differs from `state.served`, recover it and hand
/// the result to `apply` (which swaps it into the coordinator). The
/// route's served version is only advanced when `apply` succeeds, so a
/// failed recovery never drops a serving route.
///
/// `state.generation` is advanced only when **no** route failed this
/// pass. A pass with any [`SyncEvent::Failed`] leaves the generation
/// stale so the poll loop re-enters `sync_published` on its very next
/// tick — a transiently failed route recovers as soon as the failure
/// clears instead of waiting for an unrelated manifest mutation.
pub fn sync_published(
    registry: &mut Registry,
    state: &mut WatchState,
    mut apply: impl FnMut(&str, &RecoveredModel) -> Result<(), String>,
) -> Vec<SyncEvent> {
    let mut events = Vec::new();
    let targets: Vec<(String, u64)> = registry
        .routes()
        .map(|(name, e)| (name.to_string(), e.published))
        .collect();
    for (route, published) in targets {
        if state.served.get(&route) == Some(&published) {
            continue;
        }
        match registry.load_published(&route) {
            Ok(rec) => match apply(&route, &rec) {
                Ok(()) => {
                    state.served.insert(route.clone(), rec.version);
                    journal().emit(EventKind::RouteRecovered {
                        route: route.clone(),
                        version: rec.version,
                    });
                    events.push(SyncEvent::Published {
                        route,
                        version: rec.version,
                        quarantined: rec.quarantined,
                    });
                }
                Err(error) => {
                    journal().emit(EventKind::RouteFailed {
                        route: route.clone(),
                        error: error.clone(),
                    });
                    events.push(SyncEvent::Failed { route, error });
                }
            },
            // NoIntactVersion while an older version is still serving is
            // the quarantine-without-dropping case: `served` is left
            // alone, so the route keeps answering on its last good
            // snapshot and recovery is retried on the next generation.
            Err(e) => {
                let error = e.to_string();
                journal().emit(EventKind::RouteFailed {
                    route: route.clone(),
                    error: error.clone(),
                });
                events.push(SyncEvent::Failed { route, error });
            }
        }
    }
    // Only record the generation as handled when every route applied
    // cleanly. A transient failure (backend hiccup, mid-write read)
    // must be retried on the *next poll*, not parked until an
    // unrelated manifest mutation bumps the generation again.
    let any_failed = events.iter().any(|e| matches!(e, SyncEvent::Failed { .. }));
    if !any_failed {
        state.generation = registry.generation();
    }
    events
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::InferMode;
    use crate::eval::Backend;
    use crate::tm::classifier::MultiClassTM;
    use crate::tm::io;
    use crate::tm::params::TMParams;
    use crate::tm::trainer::Trainer;
    use crate::util::{BitVec, Rng};
    use std::path::PathBuf;

    fn trained(seed: u64) -> MultiClassTM {
        let params = TMParams::new(2, 8, 10).with_seed(seed);
        let mut tr = Trainer::new(params, Backend::Indexed);
        let mut rng = Rng::new(seed ^ 0xfeed);
        let samples: Vec<(BitVec, usize)> = (0..100)
            .map(|_| {
                let y = rng.bern(0.5) as usize;
                let bits: Vec<bool> =
                    (0..10).map(|k| if k == 0 { y == 0 } else { rng.bern(0.4) }).collect();
                let mut l = bits.clone();
                l.extend(bits.iter().map(|b| !b));
                (BitVec::from_bools(&l), y)
            })
            .collect();
        for _ in 0..2 {
            tr.train_epoch(samples.iter().map(|(l, y)| (l, *y)));
        }
        tr.tm
    }

    fn tmp_registry(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "tmi-watch-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn generation_observes_every_publish() {
        let dir = tmp_registry("gen");
        assert_eq!(read_generation(&dir), None);
        let mut reg = Registry::open(&dir, 4).unwrap();
        assert_eq!(read_generation(&dir), Some(0));
        let tm = trained(3);
        reg.publish("cpu", &tm, InferMode::Auto).unwrap();
        assert_eq!(read_generation(&dir), Some(1));
        // republishing *identical* content — the same-length rewrite an
        // (mtime, len) stamp can miss — still moves the generation
        reg.publish("cpu", &tm, InferMode::Auto).unwrap();
        assert_eq!(read_generation(&dir), Some(2));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn sync_publishes_new_versions_once() {
        let dir = tmp_registry("sync");
        let mut reg = Registry::open(&dir, 4).unwrap();
        let tm1 = trained(4);
        reg.publish("cpu", &tm1, InferMode::Auto).unwrap();
        let mut state = WatchState::default();
        let mut applied = Vec::new();
        let events = sync_published(&mut reg, &mut state, |route, rec| {
            applied.push((route.to_string(), rec.version, io::model_digest(&rec.tm)));
            Ok(())
        });
        assert_eq!(events.len(), 1);
        assert!(matches!(
            &events[0],
            SyncEvent::Published { route, version: 1, .. } if route == "cpu"
        ));
        assert_eq!(applied, vec![("cpu".to_string(), 1, io::model_digest(&tm1))]);
        assert_eq!(state.served.get("cpu"), Some(&1));

        // steady state: nothing to do
        let events = sync_published(&mut reg, &mut state, |_, _| {
            panic!("no new version to apply")
        });
        assert!(events.is_empty());

        // a new publish is picked up exactly once
        let tm2 = trained(5);
        reg.publish("cpu", &tm2, InferMode::Auto).unwrap();
        let mut swaps = 0;
        let events = sync_published(&mut reg, &mut state, |_, rec| {
            swaps += 1;
            assert_eq!(io::model_digest(&rec.tm), io::model_digest(&tm2));
            Ok(())
        });
        assert_eq!((events.len(), swaps), (1, 1));
        assert_eq!(state.served.get("cpu"), Some(&2));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_publish_mid_watch_keeps_route_serving() {
        let dir = tmp_registry("corrupt");
        let mut reg = Registry::open(&dir, 4).unwrap();
        let tm1 = trained(6);
        reg.publish("cpu", &tm1, InferMode::Auto).unwrap();
        let mut state = WatchState::default();
        let _ = sync_published(&mut reg, &mut state, |_, _| Ok(()));
        assert_eq!(state.served.get("cpu"), Some(&1));

        // v2 lands corrupt (bit-flipped after write)
        let tm2 = trained(7);
        reg.publish("cpu", &tm2, InferMode::Auto).unwrap();
        let f = dir.join("cpu/v000002.tm");
        let mut bytes = std::fs::read(&f).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        std::fs::write(&f, &bytes).unwrap();

        // the watch falls back to v1: recovery quarantines v2, re-loads
        // v1, and the route is *not* dropped. apply sees v1 again —
        // semantically a no-op republish of the still-good version.
        let mut applied = Vec::new();
        let events = sync_published(&mut reg, &mut state, |route, rec| {
            applied.push((route.to_string(), rec.version));
            Ok(())
        });
        assert_eq!(events.len(), 1);
        match &events[0] {
            SyncEvent::Published {
                version,
                quarantined,
                ..
            } => {
                assert_eq!(*version, 1);
                assert_eq!(quarantined, &vec![2]);
            }
            other => panic!("expected Published, got {other:?}"),
        }
        assert_eq!(state.served.get("cpu"), Some(&1));
        assert!(dir.join("quarantine/cpu-v000002.tm").exists());

        // steady state again — the quarantine is not re-processed
        let events = sync_published(&mut reg, &mut state, |_, _| {
            panic!("nothing new")
        });
        assert!(events.is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn failed_apply_is_retried_on_next_poll_without_new_publish() {
        // Regression: sync_published used to advance state.generation
        // even when a route's apply failed, so the failed route was not
        // retried until some unrelated manifest mutation. A transient
        // failure must heal on the very next poll.
        let dir = tmp_registry("retry");
        let mut reg = Registry::open(&dir, 4).unwrap();
        let tm = trained(9);
        reg.publish("cpu", &tm, InferMode::Auto).unwrap();
        let mut state = WatchState::default();

        // First pass: apply fails transiently.
        let events =
            sync_published(&mut reg, &mut state, |_, _| Err("transient".into()));
        assert_eq!(events.len(), 1);
        assert!(matches!(&events[0], SyncEvent::Failed { .. }));
        // The generation must NOT be marked handled — the poll loop's
        // `read_generation(dir) != state.generation` gate has to fire
        // again even though nothing new was published.
        assert_ne!(state.generation, reg.generation());
        assert_eq!(read_generation(&dir), Some(reg.generation()));

        // Second pass, no new publish: the failure has cleared and the
        // route is recovered.
        let mut applied = Vec::new();
        let events = sync_published(&mut reg, &mut state, |route, rec| {
            applied.push((route.to_string(), rec.version));
            Ok(())
        });
        assert_eq!(events.len(), 1);
        assert!(matches!(
            &events[0],
            SyncEvent::Published { route, version: 1, .. } if route == "cpu"
        ));
        assert_eq!(applied, vec![("cpu".to_string(), 1)]);
        assert_eq!(state.served.get("cpu"), Some(&1));
        // Now — and only now — the generation is recorded as handled.
        assert_eq!(state.generation, reg.generation());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn failed_apply_leaves_served_version_alone() {
        let dir = tmp_registry("applyfail");
        let mut reg = Registry::open(&dir, 4).unwrap();
        reg.publish("cpu", &trained(8), InferMode::Auto).unwrap();
        let mut state = WatchState::default();
        let events =
            sync_published(&mut reg, &mut state, |_, _| Err("width mismatch".into()));
        assert_eq!(events.len(), 1);
        assert!(matches!(&events[0], SyncEvent::Failed { error, .. } if error.contains("width")));
        assert!(state.served.is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
