//! The registry's route table: a small JSON document, atomically
//! rewritten on every mutation, with the previous generation kept as
//! `.bak` so a half-written rewrite never loses the registry.
//!
//! ```json
//! {"format": 1, "generation": 7, "routes": {
//!    "cpu": {"infer": "auto", "published": 3, "versions": [
//!       {"version": 2, "file": "cpu/v000002.tm", "crc32": 123, "bytes": 9182},
//!       {"version": 3, "file": "cpu/v000003.tm", "crc32": 456, "bytes": 9182}]}}}
//! ```
//!
//! `generation` increments on every store — it is what `--watch`
//! pollers compare ([`crate::registry::watch`]), so a rewrite that
//! happens to preserve mtime and length is still observed. `crc32` is
//! the digest of the complete on-disk file image (magic, body, footer),
//! letting recovery reject a damaged file without even parsing it.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use crate::engine::InferMode;
use crate::registry::store::RegistryError;
use crate::util::Json;

/// Manifest file name inside a registry directory.
pub const MANIFEST: &str = "manifest.json";
/// Scratch name the manifest is written to before the atomic rename.
pub const MANIFEST_TMP: &str = "manifest.json.tmp";
/// Name the previous manifest generation is demoted to.
pub const MANIFEST_BAK: &str = "manifest.json.bak";

/// One retained model file of one route.
#[derive(Clone, Debug, PartialEq)]
pub struct VersionEntry {
    /// Monotonic version number within the route.
    pub version: u64,
    /// Path relative to the registry root (`<route>/v000001.tm`).
    pub file: String,
    /// CRC-32 of the complete file image as written.
    pub crc32: u32,
    /// Snapshot file size in bytes.
    pub bytes: u64,
}

/// One route: engine policy, the published (serving) version, and the
/// retained version list in ascending version order.
#[derive(Clone, Debug, PartialEq)]
pub struct RouteEntry {
    /// Engine-selection policy recorded at publish time.
    pub infer: InferMode,
    /// Version number currently published (newest intact).
    pub published: u64,
    /// Retained versions, oldest first.
    pub versions: Vec<VersionEntry>,
}

/// The whole route table.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Manifest {
    /// Bumped on every publish; what `--watch` polls.
    pub generation: u64,
    /// Every route, by name.
    pub routes: BTreeMap<String, RouteEntry>,
}

/// A manifest load that may have fallen back to the `.bak` generation.
#[derive(Clone, Debug)]
pub struct LoadedManifest {
    /// The parsed manifest.
    pub manifest: Manifest,
    /// True iff `manifest.json` was missing/corrupt and `.bak` was used
    /// — the caller should rewrite the live file.
    pub from_backup: bool,
}

impl Manifest {
    /// Serialize to the on-disk JSON form.
    pub fn to_json(&self) -> Json {
        let routes: BTreeMap<String, Json> = self
            .routes
            .iter()
            .map(|(name, e)| {
                let versions: Vec<Json> = e
                    .versions
                    .iter()
                    .map(|v| {
                        Json::obj([
                            ("version", Json::num(v.version as f64)),
                            ("file", Json::str(&v.file)),
                            ("crc32", Json::num(v.crc32 as f64)),
                            ("bytes", Json::num(v.bytes as f64)),
                        ])
                    })
                    .collect();
                let entry = Json::obj([
                    ("infer", Json::str(e.infer.name())),
                    ("published", Json::num(e.published as f64)),
                    ("versions", Json::Arr(versions)),
                ]);
                (name.clone(), entry)
            })
            .collect();
        Json::obj([
            ("format", Json::num(1.0)),
            ("generation", Json::num(self.generation as f64)),
            ("routes", Json::Obj(routes)),
        ])
    }

    /// Parse the on-disk JSON form, validating shape.
    pub fn from_json(v: &Json) -> Result<Manifest, String> {
        match v.get("format").and_then(Json::as_usize) {
            Some(1) => {}
            other => return Err(format!("unsupported manifest format {other:?}")),
        }
        let generation = v
            .get("generation")
            .and_then(Json::as_usize)
            .ok_or("missing generation")? as u64;
        let Some(Json::Obj(route_map)) = v.get("routes") else {
            return Err("missing routes object".to_string());
        };
        let mut routes = BTreeMap::new();
        for (name, rv) in route_map {
            let infer: InferMode = rv
                .get("infer")
                .and_then(Json::as_str)
                .ok_or_else(|| format!("route '{name}': missing infer"))?
                .parse()
                .map_err(|e| format!("route '{name}': {e}"))?;
            let published = rv
                .get("published")
                .and_then(Json::as_usize)
                .ok_or_else(|| format!("route '{name}': missing published"))?
                as u64;
            let vs = rv
                .get("versions")
                .and_then(Json::as_arr)
                .ok_or_else(|| format!("route '{name}': missing versions"))?;
            let mut versions = Vec::with_capacity(vs.len());
            for vv in vs {
                let field = |k: &str| {
                    vv.get(k)
                        .and_then(Json::as_usize)
                        .ok_or_else(|| format!("route '{name}': version missing {k}"))
                };
                versions.push(VersionEntry {
                    version: field("version")? as u64,
                    file: vv
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| format!("route '{name}': version missing file"))?
                        .to_string(),
                    crc32: field("crc32")? as u32,
                    bytes: field("bytes")? as u64,
                });
            }
            versions.sort_by_key(|v| v.version);
            routes.insert(
                name.clone(),
                RouteEntry {
                    infer,
                    published,
                    versions,
                },
            );
        }
        Ok(Manifest { generation, routes })
    }

    /// Atomically persist: write `.tmp`, fsync, demote the live file to
    /// `.bak`, rename `.tmp` into place. A crash at any point leaves
    /// either the new manifest, the old one, or the `.bak` — never a
    /// torn live file that parses.
    pub fn store(&self, dir: &Path) -> std::io::Result<()> {
        let live = dir.join(MANIFEST);
        let tmp = dir.join(MANIFEST_TMP);
        let bak = dir.join(MANIFEST_BAK);
        {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(self.to_json().to_string().as_bytes())?;
            f.write_all(b"\n")?;
            f.sync_all()?;
        }
        if live.exists() {
            let _ = std::fs::rename(&live, &bak);
        }
        std::fs::rename(&tmp, &live)?;
        // best-effort directory fsync so the renames themselves are
        // durable (Linux requires it; other platforms may refuse)
        #[cfg(unix)]
        {
            let _ = std::fs::File::open(dir).and_then(|d| d.sync_all());
        }
        Ok(())
    }

    /// Load from `dir`, falling back to `.bak` when the live file is
    /// missing or does not parse (half-written by a crashed writer).
    /// No manifest at all means a fresh, empty registry.
    pub fn load(dir: &Path) -> Result<LoadedManifest, RegistryError> {
        match read_manifest_file(&dir.join(MANIFEST)) {
            Ok(Some(m)) => Ok(LoadedManifest {
                manifest: m,
                from_backup: false,
            }),
            live_result => match read_manifest_file(&dir.join(MANIFEST_BAK)) {
                Ok(Some(m)) => Ok(LoadedManifest {
                    manifest: m,
                    from_backup: true,
                }),
                _ => match live_result {
                    // neither file exists: fresh registry
                    Ok(None) => Ok(LoadedManifest {
                        manifest: Manifest::default(),
                        from_backup: false,
                    }),
                    Ok(Some(_)) => unreachable!("handled above"),
                    Err(e) => Err(e),
                },
            },
        }
    }
}

/// Read one manifest file: `Ok(None)` if absent, `Err` if present but
/// unreadable or unparseable.
fn read_manifest_file(path: &Path) -> Result<Option<Manifest>, RegistryError> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(RegistryError::Io(e)),
    };
    let v = Json::parse(&text)
        .map_err(|e| RegistryError::CorruptManifest(format!("{}: {e}", path.display())))?;
    Manifest::from_json(&v)
        .map(Some)
        .map_err(|e| RegistryError::CorruptManifest(format!("{}: {e}", path.display())))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Manifest {
        let mut routes = BTreeMap::new();
        routes.insert(
            "cpu".to_string(),
            RouteEntry {
                infer: InferMode::Auto,
                published: 2,
                versions: vec![
                    VersionEntry {
                        version: 1,
                        file: "cpu/v000001.tm".into(),
                        crc32: 0xDEAD_BEEF,
                        bytes: 812,
                    },
                    VersionEntry {
                        version: 2,
                        file: "cpu/v000002.tm".into(),
                        crc32: 42,
                        bytes: 813,
                    },
                ],
            },
        );
        routes.insert(
            "xla".to_string(),
            RouteEntry {
                infer: InferMode::Dense,
                published: 1,
                versions: vec![VersionEntry {
                    version: 1,
                    file: "xla/v000001.tm".into(),
                    crc32: 7,
                    bytes: 99,
                }],
            },
        );
        Manifest {
            generation: 9,
            routes,
        }
    }

    #[test]
    fn json_roundtrip_is_exact() {
        let m = sample();
        let back = Manifest::from_json(&m.to_json()).unwrap();
        assert_eq!(m, back);
        // emission is deterministic (BTreeMap keys)
        assert_eq!(m.to_json().to_string(), back.to_json().to_string());
    }

    #[test]
    fn from_json_rejects_malformed() {
        for bad in [
            r#"{"generation": 1, "routes": {}}"#,
            r#"{"format": 2, "generation": 1, "routes": {}}"#,
            r#"{"format": 1, "routes": {}}"#,
            r#"{"format": 1, "generation": 1}"#,
            r#"{"format": 1, "generation": 1, "routes": {"r": {"published": 1, "versions": []}}}"#,
            r#"{"format": 1, "generation": 1, "routes": {"r": {"infer": "warp", "published": 1, "versions": []}}}"#,
        ] {
            let v = Json::parse(bad).unwrap();
            assert!(Manifest::from_json(&v).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn store_load_with_backup_fallback() {
        let dir = std::env::temp_dir().join(format!("tmi-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let m1 = Manifest {
            generation: 1,
            ..Default::default()
        };
        m1.store(&dir).unwrap();
        let m2 = sample();
        m2.store(&dir).unwrap();
        // live is generation 9, bak holds generation 1
        let loaded = Manifest::load(&dir).unwrap();
        assert!(!loaded.from_backup);
        assert_eq!(loaded.manifest, m2);

        // half-written live file: fall back to .bak (the previous store
        // demoted m1 there)
        std::fs::write(dir.join(MANIFEST), r#"{"format": 1, "gen"#).unwrap();
        let loaded = Manifest::load(&dir).unwrap();
        assert!(loaded.from_backup);
        assert_eq!(loaded.manifest.generation, 1);

        // no manifest at all: fresh registry
        std::fs::remove_file(dir.join(MANIFEST)).unwrap();
        std::fs::remove_file(dir.join(MANIFEST_BAK)).unwrap();
        let loaded = Manifest::load(&dir).unwrap();
        assert!(!loaded.from_backup);
        assert_eq!(loaded.manifest, Manifest::default());

        // corrupt live and no bak: a typed error, not a fresh registry
        // (silently discarding a damaged route table would be data loss)
        std::fs::write(dir.join(MANIFEST), "not json").unwrap();
        assert!(matches!(
            Manifest::load(&dir),
            Err(RegistryError::CorruptManifest(_))
        ));
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
