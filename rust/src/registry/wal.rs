//! Per-route write-ahead log of online feedback events.
//!
//! The learn-while-serving loop (`feedback`/`train` protocol verbs)
//! applies labeled examples to a live [`crate::tm::Trainer`] between
//! registry publishes. A crash in that window would silently lose
//! every update since the last published snapshot — so each feedback
//! event is appended here *before* it is applied to the trainer
//! (WAL-first ordering), and the log is replayed on restart before the
//! route starts serving. At each successful registry publish the log
//! is truncated: the published snapshot now owns those updates.
//!
//! ## On-disk format
//!
//! The log lives next to the route's versioned snapshots as
//! `<registry>/<route>/feedback.wal` (the `.wal` extension keeps it
//! invisible to [`crate::registry::Registry::gc`], which only removes
//! `.tm` files). It is a flat sequence of CRC-framed records:
//!
//! ```text
//! record := len:u32le  crc:u32le  payload[len]
//! payload := version:u64le  label:u32le  n_bits:u32le  bits[ceil(n_bits/8)]
//! ```
//!
//! `version` is the registry version of the last durable publish at
//! append time ([`FeedbackWal::set_version`]): the snapshot the update
//! is *based on*. It makes truncation idempotent — replay skips
//! records whose version is below the recovered snapshot's (a crash
//! between registry publish and [`FeedbackWal::truncate`] leaves
//! records the published snapshot already owns; without the stamp they
//! would be applied a second time). `bits` packs the *literal* vector
//! exactly as handed to [`crate::tm::Trainer::train_sample`] (bit `i`
//! is bit `i % 8` of byte `i / 8`), so replay reconstructs the
//! training input without re-deriving `[x, ¬x]` from feature bits.
//! `crc` is CRC-32 over the payload ([`crate::util::crc32`], same
//! polynomial as the model file format). A torn tail — truncated
//! header, short payload, or CRC mismatch, all expected outcomes of
//! `kill -9` mid-append — is detected on open and truncated away;
//! everything before it replays.
//!
//! ## Durability
//!
//! Plain appends flush to the OS page cache only — that is exactly the
//! `kill -9` (process crash) guarantee; it does **not** survive power
//! loss or a kernel crash. [`FeedbackWal::sync`] is called at durable
//! publish boundaries, so across power loss every update is owned by
//! either a published snapshot or a synced log record, bar the window
//! since the last publish. [`FeedbackWal::set_sync_on_append`]
//! (`--wal-fsync`) closes that window too by fsyncing every append,
//! at a per-event latency cost.

use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use crate::util::{crc32, BitVec};

/// File name of a route's feedback log inside its registry directory.
pub const WAL_FILE: &str = "feedback.wal";

/// Refuse record payloads beyond this (corrupt length fields must not
/// drive allocation).
const MAX_PAYLOAD: u32 = 1 << 22;

/// One durably logged feedback event: the label and the literal
/// vector exactly as applied to the trainer, stamped with the registry
/// version of the snapshot the update is based on.
#[derive(Clone, Debug, PartialEq)]
pub struct FeedbackRecord {
    /// Last durably published registry version at append time; replay
    /// skips records below the recovered snapshot's version (already
    /// owned by it).
    pub version: u64,
    /// Label the example was tagged with.
    pub label: u32,
    /// The example's literal vector.
    pub literals: BitVec,
}

/// What [`FeedbackWal::open`] recovered from an existing log.
#[derive(Debug, Default)]
pub struct WalReplay {
    /// Intact records, oldest first — apply these to the recovered
    /// trainer in order before serving resumes.
    pub records: Vec<FeedbackRecord>,
    /// Bytes of torn tail discarded (0 on a clean log).
    pub truncated_bytes: u64,
}

/// Append handle to one route's feedback log.
pub struct FeedbackWal {
    file: File,
    path: PathBuf,
    /// Records currently in the log (replayed + appended since the
    /// last truncate).
    records: u64,
    /// Version stamped onto appended records: the last durably
    /// published registry version ([`FeedbackWal::set_version`]).
    version: u64,
    /// Opt-in fsync-per-append (`--wal-fsync`): survive power loss,
    /// not just `kill -9`.
    sync_on_append: bool,
}

impl FeedbackWal {
    /// The log path for a route directory.
    pub fn route_path(route_dir: &Path) -> PathBuf {
        route_dir.join(WAL_FILE)
    }

    /// Open (creating if absent) a route's log, scan it, truncate any
    /// torn tail, and return the append handle plus the replayable
    /// records. The handle appends after the last intact record.
    pub fn open(path: &Path) -> std::io::Result<(FeedbackWal, WalReplay)> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .open(path)?;
        let mut bytes = Vec::new();
        file.read_to_end(&mut bytes)?;
        let mut replay = WalReplay::default();
        let mut offset = 0usize;
        while let Some((record, next)) = parse_record(&bytes, offset) {
            replay.records.push(record);
            offset = next;
        }
        if offset < bytes.len() {
            replay.truncated_bytes = (bytes.len() - offset) as u64;
            file.set_len(offset as u64)?;
        }
        file.seek(SeekFrom::Start(offset as u64))?;
        let records = replay.records.len() as u64;
        Ok((
            FeedbackWal {
                file,
                path: path.to_path_buf(),
                records,
                version: 0,
                sync_on_append: false,
            },
            replay,
        ))
    }

    /// Set the version stamped onto subsequent appends: the registry
    /// version of the last durable publish (the snapshot the updates
    /// are based on). Call after opening (recovered version) and after
    /// every durable publish — even a failed [`FeedbackWal::truncate`]
    /// then stays benign, because replay skips the stale records.
    pub fn set_version(&mut self, version: u64) {
        self.version = version;
    }

    /// Version currently stamped onto appends.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Opt into fsync-per-append (`--wal-fsync`): every append reaches
    /// stable storage before the caller acks, surviving power loss —
    /// default off, where appends survive `kill -9` only.
    pub fn set_sync_on_append(&mut self, on: bool) {
        self.sync_on_append = on;
    }

    /// Append one event and flush it to the OS before returning —
    /// the caller applies the update to the trainer only after this
    /// succeeds (WAL-first ordering makes `kill -9` replay exact; with
    /// [`FeedbackWal::set_sync_on_append`] the event is also fsynced).
    pub fn append(&mut self, label: u32, literals: &BitVec) -> std::io::Result<()> {
        let payload = encode_payload(self.version, label, literals);
        let mut frame = Vec::with_capacity(8 + payload.len());
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(&crc32(&payload).to_le_bytes());
        frame.extend_from_slice(&payload);
        self.file.write_all(&frame)?;
        self.file.flush()?;
        if self.sync_on_append {
            self.file.sync_data()?;
        }
        self.records += 1;
        Ok(())
    }

    /// Drop every logged event: the updates are now owned by a
    /// successfully published snapshot.
    pub fn truncate(&mut self) -> std::io::Result<()> {
        self.file.set_len(0)?;
        self.file.seek(SeekFrom::Start(0))?;
        self.records = 0;
        Ok(())
    }

    /// Force the log contents to stable storage (durable publish
    /// points; plain appends only flush to the OS).
    pub fn sync(&mut self) -> std::io::Result<()> {
        self.file.sync_data()
    }

    /// Records currently in the log.
    pub fn records(&self) -> u64 {
        self.records
    }

    /// Path of the log file.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

fn encode_payload(version: u64, label: u32, literals: &BitVec) -> Vec<u8> {
    let n_bits = literals.len();
    let mut payload = Vec::with_capacity(16 + n_bits.div_ceil(8));
    payload.extend_from_slice(&version.to_le_bytes());
    payload.extend_from_slice(&label.to_le_bytes());
    payload.extend_from_slice(&(n_bits as u32).to_le_bytes());
    let mut byte = 0u8;
    for i in 0..n_bits {
        if literals.get(i) {
            byte |= 1 << (i % 8);
        }
        if i % 8 == 7 {
            payload.push(byte);
            byte = 0;
        }
    }
    if n_bits % 8 != 0 {
        payload.push(byte);
    }
    payload
}

/// Parse the record at `offset`; `None` marks end-of-log or a torn
/// tail (the caller truncates from `offset`).
fn parse_record(bytes: &[u8], offset: usize) -> Option<(FeedbackRecord, usize)> {
    let header = bytes.get(offset..offset + 8)?;
    let len = u32::from_le_bytes(header[0..4].try_into().unwrap());
    let crc = u32::from_le_bytes(header[4..8].try_into().unwrap());
    if len > MAX_PAYLOAD {
        return None;
    }
    let payload = bytes.get(offset + 8..offset + 8 + len as usize)?;
    if crc32(payload) != crc {
        return None;
    }
    let record = decode_payload(payload)?;
    Some((record, offset + 8 + len as usize))
}

fn decode_payload(payload: &[u8]) -> Option<FeedbackRecord> {
    if payload.len() < 16 {
        return None;
    }
    let version = u64::from_le_bytes(payload[0..8].try_into().unwrap());
    let label = u32::from_le_bytes(payload[8..12].try_into().unwrap());
    let n_bits = u32::from_le_bytes(payload[12..16].try_into().unwrap()) as usize;
    let packed = payload.get(16..)?;
    if packed.len() != n_bits.div_ceil(8) {
        return None;
    }
    let mut literals = BitVec::zeros(n_bits);
    for i in 0..n_bits {
        if packed[i / 8] >> (i % 8) & 1 == 1 {
            literals.set(i);
        }
    }
    Some(FeedbackRecord {
        version,
        label,
        literals,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_wal(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "tmi-wal-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(WAL_FILE)
    }

    fn lits(pattern: &[bool]) -> BitVec {
        BitVec::from_bools(pattern)
    }

    #[test]
    fn roundtrip_append_then_replay() {
        let path = tmp_wal("roundtrip");
        let (mut wal, replay) = FeedbackWal::open(&path).unwrap();
        assert!(replay.records.is_empty());
        assert_eq!(replay.truncated_bytes, 0);
        let a = lits(&[true, false, true, true, false, false, true, false, true]);
        let b = lits(&[false; 16]);
        wal.set_version(3);
        wal.append(1, &a).unwrap();
        wal.set_version(4);
        wal.append(0, &b).unwrap();
        assert_eq!(wal.records(), 2);
        drop(wal);
        let (wal, replay) = FeedbackWal::open(&path).unwrap();
        assert_eq!(wal.records(), 2);
        assert_eq!(replay.truncated_bytes, 0);
        assert_eq!(replay.records.len(), 2);
        // the per-record version stamp round-trips: it is what lets
        // replay skip records an already-published snapshot owns
        assert_eq!(
            replay.records[0],
            FeedbackRecord { version: 3, label: 1, literals: a }
        );
        assert_eq!(
            replay.records[1],
            FeedbackRecord { version: 4, label: 0, literals: b }
        );
    }

    #[test]
    fn torn_tail_is_truncated_and_survivors_replay() {
        let path = tmp_wal("torn");
        let (mut wal, _) = FeedbackWal::open(&path).unwrap();
        let a = lits(&[true, true, false, true]);
        wal.append(3, &a).unwrap();
        wal.append(2, &a).unwrap();
        drop(wal);
        // simulate kill -9 mid-append: a partial frame at the tail
        let clean_len = std::fs::metadata(&path).unwrap().len();
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(&[0x55, 0xAA, 0x01]).unwrap();
        drop(f);
        let (mut wal, replay) = FeedbackWal::open(&path).unwrap();
        assert_eq!(replay.records.len(), 2);
        assert_eq!(replay.truncated_bytes, 3);
        assert_eq!(std::fs::metadata(&path).unwrap().len(), clean_len);
        // the handle appends cleanly after truncation
        wal.append(1, &a).unwrap();
        drop(wal);
        let (_, replay) = FeedbackWal::open(&path).unwrap();
        assert_eq!(replay.records.len(), 3);
        assert_eq!(replay.records[2].label, 1);
    }

    #[test]
    fn crc_mismatch_drops_tail_from_damaged_record() {
        let path = tmp_wal("crc");
        let (mut wal, _) = FeedbackWal::open(&path).unwrap();
        let a = lits(&[true; 12]);
        wal.append(1, &a).unwrap();
        let first_len = std::fs::metadata(&path).unwrap().len();
        wal.append(0, &a).unwrap();
        wal.append(1, &a).unwrap();
        drop(wal);
        // flip a payload bit inside the second record
        let mut bytes = std::fs::read(&path).unwrap();
        let idx = first_len as usize + 9;
        bytes[idx] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let (_, replay) = FeedbackWal::open(&path).unwrap();
        // record 2 fails its CRC; it and everything after are dropped
        assert_eq!(replay.records.len(), 1);
        assert!(replay.truncated_bytes > 0);
        assert_eq!(std::fs::metadata(&path).unwrap().len(), first_len);
    }

    #[test]
    fn truncate_resets_the_log() {
        let path = tmp_wal("truncate");
        let (mut wal, _) = FeedbackWal::open(&path).unwrap();
        wal.append(1, &lits(&[true, false, true])).unwrap();
        wal.truncate().unwrap();
        assert_eq!(wal.records(), 0);
        assert_eq!(std::fs::metadata(&path).unwrap().len(), 0);
        // appends after truncate start a fresh record stream
        wal.append(0, &lits(&[false, true])).unwrap();
        wal.sync().unwrap();
        drop(wal);
        let (_, replay) = FeedbackWal::open(&path).unwrap();
        assert_eq!(replay.records.len(), 1);
        assert_eq!(replay.records[0].label, 0);
        assert_eq!(replay.records[0].literals.len(), 2);
    }

    #[test]
    fn oversized_length_field_is_a_torn_tail() {
        let path = tmp_wal("oversize");
        let (mut wal, _) = FeedbackWal::open(&path).unwrap();
        wal.append(1, &lits(&[true])).unwrap();
        drop(wal);
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(&u32::MAX.to_le_bytes()).unwrap();
        f.write_all(&[0u8; 4]).unwrap();
        drop(f);
        let (_, replay) = FeedbackWal::open(&path).unwrap();
        assert_eq!(replay.records.len(), 1);
        assert_eq!(replay.truncated_bytes, 8);
    }
}
