//! [`Registry`]: publish, recover, verify, and garbage-collect
//! versioned model snapshots on disk.
//!
//! Every mutation is crash-ordered (tmp + fsync + rename) and bumps the
//! manifest generation. Recovery ([`Registry::load_published`]) walks a
//! route's versions newest-first, validating each file's recorded
//! CRC-32 digest *before* parsing it; damaged files are moved to
//! `quarantine/` and dropped from the manifest, and the newest intact
//! version wins. Only a route with no intact version at all fails — and
//! that failure is a typed error the server turns into "skip this
//! route", never a panic.
//!
//! Online learning stores its write-ahead log *inside* each route's
//! directory (`<route>/feedback.wal`, see [`crate::registry::wal`]):
//! publish/recovery never touch it, and [`Registry::gc`] only ever
//! removes `.tm` snapshot files, so retention can never eat feedback
//! events that are not yet owned by a published snapshot.

use std::collections::BTreeSet;
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::engine::InferMode;
use crate::obs::{journal, EventKind};
use crate::registry::manifest::{Manifest, RouteEntry, VersionEntry};
use crate::tm::classifier::MultiClassTM;
use crate::tm::io::{self, ModelIoError};
use crate::util::crc32;

/// Subdirectory (under the registry root) for damaged files.
pub const QUARANTINE_DIR: &str = "quarantine";

/// Default number of versions retained per route.
pub const DEFAULT_RETAIN: usize = 4;

/// Typed registry failure.
#[derive(Debug)]
pub enum RegistryError {
    /// Filesystem failure.
    Io(std::io::Error),
    /// The manifest (and its backup) exists but cannot be parsed.
    CorruptManifest(String),
    /// No route with that name in the manifest.
    UnknownRoute(String),
    /// Every retained version of the route failed its digest or parse
    /// check; all were quarantined.
    NoIntactVersion(String),
    /// Route names are path components: `[A-Za-z0-9_-]{1,64}` only.
    BadRouteName(String),
    /// Snapshot file failed checksum or parse (typed model error).
    Model(ModelIoError),
}

impl std::fmt::Display for RegistryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RegistryError::Io(e) => write!(f, "registry io error: {e}"),
            RegistryError::CorruptManifest(why) => write!(f, "corrupt manifest: {why}"),
            RegistryError::UnknownRoute(r) => write!(f, "unknown route '{r}'"),
            RegistryError::NoIntactVersion(r) => {
                write!(f, "route '{r}': no intact version (all quarantined)")
            }
            RegistryError::BadRouteName(r) => write!(
                f,
                "bad route name '{r}': use 1-64 chars of [A-Za-z0-9_-]"
            ),
            RegistryError::Model(e) => write!(f, "model file error: {e}"),
        }
    }
}

impl std::error::Error for RegistryError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            RegistryError::Io(e) => Some(e),
            RegistryError::Model(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for RegistryError {
    fn from(e: std::io::Error) -> Self {
        RegistryError::Io(e)
    }
}

impl From<ModelIoError> for RegistryError {
    fn from(e: ModelIoError) -> Self {
        RegistryError::Model(e)
    }
}

/// A recovered serving model plus what recovery had to discard to get
/// it.
#[derive(Debug)]
pub struct RecoveredModel {
    /// The recovered machine.
    pub tm: MultiClassTM,
    /// Registry version the machine was loaded from.
    pub version: u64,
    /// Engine-selection policy recorded at publish time.
    pub infer: InferMode,
    /// Versions quarantined (newest-first) before an intact one loaded.
    pub quarantined: Vec<u64>,
}

/// One `verify` finding: a recorded version whose file is damaged.
#[derive(Clone, Debug, PartialEq)]
pub struct VerifyIssue {
    /// Route the damaged file belongs to.
    pub route: String,
    /// Version of the damaged file.
    pub version: u64,
    /// File name inside the route directory.
    pub file: String,
    /// Human-readable diagnosis.
    pub why: String,
}

/// What `gc` removed.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct GcReport {
    /// On-disk `.tm` files not referenced by the manifest.
    pub removed_files: usize,
    /// Manifest entries pruned down to the retention bound.
    pub pruned_versions: usize,
}

/// Handle to an open on-disk registry. All mutations persist the
/// manifest before returning.
pub struct Registry {
    dir: PathBuf,
    retain: usize,
    manifest: Manifest,
}

fn valid_route_name(name: &str) -> bool {
    !name.is_empty()
        && name.len() <= 64
        && name
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
}

impl Registry {
    /// Open (creating if absent) the registry at `dir`, retaining up to
    /// `retain` versions per route. Falls back to the `.bak` manifest if
    /// the live one is torn, and heals the live file in that case.
    pub fn open(dir: impl Into<PathBuf>, retain: usize) -> Result<Registry, RegistryError> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        let loaded = Manifest::load(&dir)?;
        let reg = Registry {
            dir,
            retain: retain.max(1),
            manifest: loaded.manifest,
        };
        if loaded.from_backup {
            reg.manifest.store(&reg.dir)?;
        }
        Ok(reg)
    }

    /// The registry's root directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Monotonic change counter — what `--watch` pollers compare.
    pub fn generation(&self) -> u64 {
        self.manifest.generation
    }

    /// Every route in the manifest, by name.
    pub fn routes(&self) -> impl Iterator<Item = (&str, &RouteEntry)> {
        self.manifest.routes.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// The manifest entry for `name`, if present.
    pub fn route(&self, name: &str) -> Option<&RouteEntry> {
        self.manifest.routes.get(name)
    }

    /// Persist the manifest (used by graceful shutdown; every mutation
    /// already stores it, so this is a no-op unless the caller mutated
    /// state since).
    pub fn flush(&self) -> Result<(), RegistryError> {
        self.manifest.store(&self.dir)?;
        Ok(())
    }

    /// Publish `tm` as the next version of `route`: write the
    /// checksummed v3 file (tmp + fsync + rename), record it in the
    /// manifest, prune retention, bump the generation. Returns the new
    /// version number.
    pub fn publish(
        &mut self,
        route: &str,
        tm: &MultiClassTM,
        infer: InferMode,
    ) -> Result<u64, RegistryError> {
        if !valid_route_name(route) {
            return Err(RegistryError::BadRouteName(route.to_string()));
        }
        let bytes = io::serialize(tm);
        let digest = crc32(&bytes);
        let entry = self
            .manifest
            .routes
            .entry(route.to_string())
            .or_insert_with(|| RouteEntry {
                infer,
                published: 0,
                versions: Vec::new(),
            });
        let version = entry
            .versions
            .last()
            .map(|v| v.version)
            .unwrap_or(0)
            .max(entry.published)
            + 1;
        let rel = format!("{route}/v{version:06}.tm");
        let abs = self.dir.join(&rel);
        std::fs::create_dir_all(abs.parent().expect("versioned file has a parent"))?;
        let tmp = abs.with_extension("tm.tmp");
        {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(&bytes)?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, &abs)?;
        entry.infer = infer;
        entry.published = version;
        entry.versions.push(VersionEntry {
            version,
            file: rel,
            crc32: digest,
            bytes: bytes.len() as u64,
        });
        while entry.versions.len() > self.retain {
            let old = entry.versions.remove(0);
            let _ = std::fs::remove_file(self.dir.join(&old.file));
        }
        self.manifest.generation += 1;
        self.manifest.store(&self.dir)?;
        Ok(version)
    }

    /// Recover the newest intact version of `route`: validate the
    /// recorded digest, then parse the checksummed file. Damaged
    /// versions are quarantined and recovery falls back to the next
    /// newest; only a route with nothing intact fails.
    pub fn load_published(&mut self, route: &str) -> Result<RecoveredModel, RegistryError> {
        if !self.manifest.routes.contains_key(route) {
            return Err(RegistryError::UnknownRoute(route.to_string()));
        }
        let mut quarantined = Vec::new();
        loop {
            let Some(v) = self
                .manifest
                .routes
                .get(route)
                .and_then(|e| e.versions.last())
                .cloned()
            else {
                if !quarantined.is_empty() {
                    self.manifest.generation += 1;
                    self.manifest.store(&self.dir)?;
                }
                return Err(RegistryError::NoIntactVersion(route.to_string()));
            };
            match check_and_load(&self.dir.join(&v.file), v.crc32) {
                Ok(tm) => {
                    let entry = self
                        .manifest
                        .routes
                        .get_mut(route)
                        .expect("checked above");
                    let drifted = entry.published != v.version;
                    entry.published = v.version;
                    let infer = entry.infer;
                    if drifted || !quarantined.is_empty() {
                        self.manifest.generation += 1;
                        self.manifest.store(&self.dir)?;
                    }
                    return Ok(RecoveredModel {
                        tm,
                        version: v.version,
                        infer,
                        quarantined,
                    });
                }
                Err(why) => {
                    self.quarantine_file(route, &v);
                    journal().emit(EventKind::Quarantine {
                        route: route.to_string(),
                        version: v.version,
                        reason: why,
                    });
                    quarantined.push(v.version);
                    self.manifest
                        .routes
                        .get_mut(route)
                        .expect("checked above")
                        .versions
                        .pop();
                }
            }
        }
    }

    /// Move a damaged version's file into `quarantine/` (best-effort:
    /// an already-missing file has nothing to move).
    fn quarantine_file(&self, route: &str, v: &VersionEntry) {
        let qdir = self.dir.join(QUARANTINE_DIR);
        let _ = std::fs::create_dir_all(&qdir);
        let dest = qdir.join(format!("{route}-v{:06}.tm", v.version));
        let _ = std::fs::rename(self.dir.join(&v.file), dest);
    }

    /// Read-only integrity sweep over every recorded version.
    pub fn verify(&self) -> Vec<VerifyIssue> {
        let mut issues = Vec::new();
        for (route, entry) in &self.manifest.routes {
            for v in &entry.versions {
                if let Err(why) = check_and_load(&self.dir.join(&v.file), v.crc32) {
                    issues.push(VerifyIssue {
                        route: route.clone(),
                        version: v.version,
                        file: v.file.clone(),
                        why,
                    });
                }
            }
        }
        issues
    }

    /// Prune to the retention bound and delete on-disk `.tm` files the
    /// manifest no longer references (quarantine is never touched).
    pub fn gc(&mut self) -> Result<GcReport, RegistryError> {
        let mut report = GcReport::default();
        for entry in self.manifest.routes.values_mut() {
            while entry.versions.len() > self.retain {
                let old = entry.versions.remove(0);
                let _ = std::fs::remove_file(self.dir.join(&old.file));
                report.pruned_versions += 1;
            }
        }
        let referenced: BTreeSet<PathBuf> = self
            .manifest
            .routes
            .values()
            .flat_map(|e| e.versions.iter())
            .map(|v| self.dir.join(&v.file))
            .collect();
        for route_dir in std::fs::read_dir(&self.dir)? {
            let route_dir = route_dir?.path();
            if !route_dir.is_dir()
                || route_dir.file_name().is_some_and(|n| n == QUARANTINE_DIR)
            {
                continue;
            }
            for f in std::fs::read_dir(&route_dir)? {
                let f = f?.path();
                let is_tm = f.extension().is_some_and(|e| e == "tm");
                if is_tm && !referenced.contains(&f) {
                    std::fs::remove_file(&f)?;
                    report.removed_files += 1;
                }
            }
        }
        if report.pruned_versions > 0 {
            self.manifest.generation += 1;
            self.manifest.store(&self.dir)?;
        }
        Ok(report)
    }
}

/// Validate the recorded whole-file digest, then parse. The digest
/// check catches truncation and bit flips without parsing; the parse
/// (which re-verifies the embedded v3 footer) catches everything else.
fn check_and_load(path: &Path, want_crc: u32) -> Result<MultiClassTM, String> {
    let bytes = std::fs::read(path).map_err(|e| format!("unreadable: {e}"))?;
    let got = crc32(&bytes);
    if got != want_crc {
        return Err(format!(
            "digest mismatch (manifest {want_crc:#010x}, file {got:#010x})"
        ));
    }
    io::load_from(&mut bytes.as_slice()).map_err(|e| e.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::Backend;
    use crate::tm::params::TMParams;
    use crate::tm::trainer::Trainer;
    use crate::util::{BitVec, Rng};

    fn trained(seed: u64) -> MultiClassTM {
        let params = TMParams::new(2, 8, 10).with_seed(seed);
        let mut tr = Trainer::new(params, Backend::Indexed);
        let mut rng = Rng::new(seed ^ 0x5ca1e);
        let samples: Vec<(BitVec, usize)> = (0..100)
            .map(|_| {
                let y = rng.bern(0.5) as usize;
                let bits: Vec<bool> =
                    (0..10).map(|k| if k == 0 { y == 0 } else { rng.bern(0.4) }).collect();
                let mut l = bits.clone();
                l.extend(bits.iter().map(|b| !b));
                (BitVec::from_bools(&l), y)
            })
            .collect();
        for _ in 0..2 {
            tr.train_epoch(samples.iter().map(|(l, y)| (l, *y)));
        }
        tr.tm
    }

    fn tmp_registry(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "tmi-registry-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn publish_recover_roundtrip_is_bit_identical() {
        let dir = tmp_registry("roundtrip");
        let mut reg = Registry::open(&dir, 4).unwrap();
        let tm = trained(3);
        let v = reg.publish("cpu", &tm, InferMode::Auto).unwrap();
        assert_eq!(v, 1);
        assert_eq!(reg.generation(), 1);

        // a fresh handle (restart) recovers from the manifest alone
        let mut reg2 = Registry::open(&dir, 4).unwrap();
        let rec = reg2.load_published("cpu").unwrap();
        assert_eq!(rec.version, 1);
        assert_eq!(rec.infer, InferMode::Auto);
        assert!(rec.quarantined.is_empty());
        assert_eq!(io::model_digest(&rec.tm), io::model_digest(&tm));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn retention_prunes_oldest_versions_and_files() {
        let dir = tmp_registry("retain");
        let mut reg = Registry::open(&dir, 2).unwrap();
        let tm = trained(4);
        for want in 1..=5u64 {
            assert_eq!(reg.publish("cpu", &tm, InferMode::Auto).unwrap(), want);
        }
        let entry = reg.route("cpu").unwrap();
        let kept: Vec<u64> = entry.versions.iter().map(|v| v.version).collect();
        assert_eq!(kept, vec![4, 5]);
        assert_eq!(entry.published, 5);
        assert!(!dir.join("cpu/v000001.tm").exists());
        assert!(dir.join("cpu/v000005.tm").exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn truncated_newest_falls_back_and_quarantines() {
        let dir = tmp_registry("trunc");
        let mut reg = Registry::open(&dir, 4).unwrap();
        let tm1 = trained(5);
        let tm2 = trained(6);
        reg.publish("cpu", &tm1, InferMode::Auto).unwrap();
        reg.publish("cpu", &tm2, InferMode::Auto).unwrap();
        // tear v2 in half (simulates a crash mid-write that somehow
        // bypassed the atomic rename)
        let v2 = dir.join("cpu/v000002.tm");
        let bytes = std::fs::read(&v2).unwrap();
        std::fs::write(&v2, &bytes[..bytes.len() / 2]).unwrap();

        let mut reg = Registry::open(&dir, 4).unwrap();
        let rec = reg.load_published("cpu").unwrap();
        assert_eq!(rec.version, 1, "fell back to the intact version");
        assert_eq!(rec.quarantined, vec![2]);
        assert_eq!(io::model_digest(&rec.tm), io::model_digest(&tm1));
        assert!(dir.join("quarantine/cpu-v000002.tm").exists());
        assert!(!v2.exists());
        // the manifest was rewritten: a second recovery is clean
        let mut reg = Registry::open(&dir, 4).unwrap();
        let rec = reg.load_published("cpu").unwrap();
        assert_eq!(rec.version, 1);
        assert!(rec.quarantined.is_empty());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn all_versions_corrupt_is_a_typed_error() {
        let dir = tmp_registry("allbad");
        let mut reg = Registry::open(&dir, 4).unwrap();
        reg.publish("cpu", &trained(7), InferMode::Auto).unwrap();
        // bit-flip the only version
        let f = dir.join("cpu/v000001.tm");
        let mut bytes = std::fs::read(&f).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&f, &bytes).unwrap();

        let mut reg = Registry::open(&dir, 4).unwrap();
        assert!(matches!(
            reg.load_published("cpu"),
            Err(RegistryError::NoIntactVersion(_))
        ));
        assert!(dir.join("quarantine/cpu-v000001.tm").exists());
        assert!(matches!(
            reg.load_published("nope"),
            Err(RegistryError::UnknownRoute(_))
        ));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn verify_reports_damage_without_mutating() {
        let dir = tmp_registry("verify");
        let mut reg = Registry::open(&dir, 4).unwrap();
        reg.publish("a", &trained(8), InferMode::Auto).unwrap();
        reg.publish("b", &trained(9), InferMode::Sparse).unwrap();
        assert!(reg.verify().is_empty());
        let f = dir.join("a/v000001.tm");
        let mut bytes = std::fs::read(&f).unwrap();
        bytes[10] ^= 1;
        std::fs::write(&f, &bytes).unwrap();
        let issues = reg.verify();
        assert_eq!(issues.len(), 1);
        assert_eq!(issues[0].route, "a");
        assert_eq!(issues[0].version, 1);
        assert!(issues[0].why.contains("digest mismatch"), "{}", issues[0].why);
        // verify did not quarantine or rewrite anything
        assert!(f.exists());
        assert_eq!(reg.generation(), 2);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn gc_removes_unreferenced_files_and_prunes() {
        let dir = tmp_registry("gc");
        let mut reg = Registry::open(&dir, 4).unwrap();
        let tm = trained(10);
        for _ in 0..3 {
            reg.publish("cpu", &tm, InferMode::Auto).unwrap();
        }
        // an orphan file the manifest knows nothing about
        std::fs::write(dir.join("cpu/v000099.tm"), b"orphan").unwrap();
        // retention shrinks on reopen: gc prunes down to it
        let mut reg = Registry::open(&dir, 1).unwrap();
        let report = reg.gc().unwrap();
        assert_eq!(report.removed_files, 1);
        assert_eq!(report.pruned_versions, 2);
        assert!(!dir.join("cpu/v000099.tm").exists());
        assert!(!dir.join("cpu/v000001.tm").exists());
        assert!(dir.join("cpu/v000003.tm").exists());
        let mut reg = Registry::open(&dir, 1).unwrap();
        assert!(reg.load_published("cpu").is_ok());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn bad_route_names_are_rejected() {
        let dir = tmp_registry("names");
        let mut reg = Registry::open(&dir, 4).unwrap();
        let tm = trained(11);
        for bad in ["", "../escape", "a/b", "a b", &"x".repeat(65)] {
            assert!(
                matches!(
                    reg.publish(bad, &tm, InferMode::Auto),
                    Err(RegistryError::BadRouteName(_))
                ),
                "accepted route name {bad:?}"
            );
        }
        assert!(reg.publish("ok_name-1", &tm, InferMode::Auto).is_ok());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
