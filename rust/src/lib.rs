//! # tsetlin-index
//!
//! A production-grade reproduction of *"Increasing the Inference and
//! Learning Speed of Tsetlin Machines with Clause Indexing"* (Gorji,
//! Granmo, Glimsdal, Edwards, Goodwin — 2020).
//!
//! The crate implements the full Tsetlin Machine substrate (TA teams,
//! clause banks, Type I/II feedback, multi-class training) together with
//! the paper's contribution: **clause indexing** — per-literal inclusion
//! lists plus a position matrix supporting O(1) insert/delete — which
//! evaluates clauses by *falsification* instead of exhaustive scanning.
//!
//! Architecture (see `DESIGN.md`):
//!
//! * [`tm`] — the machine itself: parameters, clause banks, feedback,
//!   multi-class classifier and trainer.
//! * [`index`] — the paper's indexing structure and the falsification
//!   evaluator.
//! * [`eval`] — baseline evaluators (the paper's exhaustive scan, plus a
//!   bit-parallel ablation) behind a common trait.
//! * [`engine`] — the batched, class-fused inference engine: one
//!   falsification walk per sample scores every class, batches shard
//!   across threads over a shared read-only index. Includes the O(nnz)
//!   sparse-delta engine for k-hot workloads (all-zeros baseline plus
//!   per-literal delta lists; auto-selected by input density).
//! * [`parallel`] — clause-sharded asynchronous parallel *training*
//!   (arXiv 2009.04861 scheme): per-worker clause shards with their own
//!   O(1)-maintained falsification indexes, a shared atomic vote tally
//!   read slightly stale, shards reassembled into the global machine
//!   every epoch.
//! * [`data`] — datasets: IDX/MNIST loading, k-threshold binarization,
//!   calibrated synthetic generators (MNIST-like, Fashion-like, IMDb-like
//!   bag-of-words).
//! * [`runtime`] — PJRT executor loading AOT-compiled XLA artifacts
//!   produced by `python/compile/aot.py` (Layer 1/2 of the stack).
//! * [`cluster`] — scale-out serving: a deterministic consistent-hash
//!   ring, a heartbeat/replication control plane (`tmi control`), line
//!   protocol nodes (`tmi serve --node-id`), and a deadline/failover
//!   request router (`tmi route`), all speaking the existing protocol
//!   and reusing the registry's checksummed images for replication.
//! * [`coordinator`] — serving layer (std::thread + condvar queues):
//!   hot-swap snapshot registry, bounded queues with load shedding,
//!   dynamic batcher workers, CPU-indexed and XLA backends, metrics,
//!   TCP front end, and the `tmi loadgen` load generator.
//! * [`obs`] — dependency-free observability: the reusable
//!   power-of-two [`obs::Histogram`], per-stage request tracing,
//!   engine index-efficiency probes, Prometheus text exposition, and
//!   the bounded structured event journal every subsystem emits into.
//! * [`registry`] — the durable side of serving: an on-disk versioned
//!   snapshot store (checksummed model files + an atomically-rewritten
//!   JSON manifest) with retention, quarantine of torn/corrupt files,
//!   and crash recovery — `tmi serve --registry` rebuilds its whole
//!   route table from the manifest alone.
//! * [`bench_harness`] — regenerates every table and figure of the
//!   paper's evaluation section.
//! * [`util`] — deterministic RNG, bit vectors, the 4-wide SIMD kernel
//!   layer ([`util::simd`]), a compact hash map, and timing helpers (no
//!   external deps on the hot path).
//!
//! `docs/ARCHITECTURE.md` maps these modules onto the system's layer
//! diagram and states the invariants each boundary guarantees;
//! `docs/PROTOCOL.md` is the wire-protocol reference and
//! `docs/TUNING.md` the operator's guide to the performance knobs.

#![warn(missing_docs)]

pub mod bench_harness;
pub mod cluster;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod eval;
pub mod index;
pub mod obs;
pub mod parallel;
pub mod registry;
pub mod runtime;
pub mod tm;
pub mod util;

pub use data::{SparseDataset, SparseSample};
pub use engine::{BatchScorer, FusedEngine, InferMode, SparseEngine};
pub use eval::Backend;
pub use parallel::ParallelTrainer;
pub use tm::classifier::MultiClassTM;
pub use tm::params::TMParams;
pub use tm::trainer::Trainer;
